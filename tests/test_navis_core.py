"""NAVIS core behaviour: graph build, CASR, insert, entrance, engine e2e."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container: seeded shim
    from _prop import given, settings, st

from repro.core import (Engine, brute_force_topk, check_invariants, preset,
                        recall_at_k, robust_prune)
from repro.core import casr as casr_mod
from repro.core import entrance as ent_mod
from repro.core import pq as pq_mod
from repro.core.iomodel import IOCounters
from repro.data import insert_stream, query_stream

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------------------------------
# graph build
# ---------------------------------------------------------------------------

def test_build_invariants(navis):
    _, state = navis
    inv = check_invariants(state.store)
    assert all(bool(v) for v in inv.values()), inv


def test_build_connectivity(navis, dataset):
    _, state = navis
    n = int(state.store.count)
    E = np.asarray(state.store.edges[:n])
    seen = np.zeros(n, bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        nxt = []
        for u in frontier:
            for v in E[u]:
                if v >= 0 and not seen[v]:
                    seen[v] = True
                    nxt.append(int(v))
        frontier = nxt
    assert seen.mean() > 0.98, seen.mean()


def test_robust_prune_properties():
    k = jax.random.PRNGKey(5)
    vecs = jax.random.normal(k, (100, 16))
    q = jax.random.normal(jax.random.fold_in(k, 1), (16,))
    cand = jnp.arange(50, dtype=jnp.int32)
    d = pq_mod.exact_l2(q, vecs[cand])
    kept = robust_prune(q, cand, d, vecs, alpha=1.2, r=12)
    kept_np = np.asarray(kept)
    live = kept_np[kept_np >= 0]
    # no duplicates
    assert len(live) == len(set(live.tolist()))
    # the closest candidate is always kept first
    assert live[0] == int(jnp.argmin(d))


# ---------------------------------------------------------------------------
# CASR (Algorithm 1)
# ---------------------------------------------------------------------------

def test_casr_full_load_matches_full_rerank(navis, dataset):
    """s = |pool| degenerates to a full fetch: exact top-k must equal the
    brute-force rerank of the pool."""
    eng, state = navis
    q = dataset["queries"][0]
    spec = eng.spec
    lut = pq_mod.adc_lut(eng.codec, q)
    from repro.core import search as search_mod
    entries, _ = eng._entries(state, lut)
    res = search_mod.disk_traverse(
        state.store, spec.lspec, lut, state.codes, state.cache,
        IOCounters.zeros(), entries, pool_size=spec.e_search,
        beam_width=4, max_hops=64)
    cres = casr_mod.casr_rerank(state.store, spec.lspec, q, res.pool_ids,
                                IOCounters.zeros(), k=10,
                                s=spec.e_search)
    valid = res.pool_ids >= 0
    d = jnp.where(valid, pq_mod.exact_l2(
        q, state.store.vectors[jnp.maximum(res.pool_ids, 0)]), jnp.inf)
    want = res.pool_ids[jnp.argsort(d)[:10]]
    np.testing.assert_array_equal(np.asarray(cres.topk_ids),
                                  np.asarray(want))


@pytest.mark.parametrize("s", [1, 4, 16])
def test_casr_loads_bounded_and_counted(navis, dataset, s):
    eng, state = navis
    q = dataset["queries"][1]
    spec = eng.spec
    lut = pq_mod.adc_lut(eng.codec, q)
    from repro.core import search as search_mod
    entries, _ = eng._entries(state, lut)
    res = search_mod.disk_traverse(
        state.store, spec.lspec, lut, state.codes, state.cache,
        IOCounters.zeros(), entries, pool_size=spec.e_search,
        beam_width=4, max_hops=64)
    cres = casr_mod.casr_rerank(state.store, spec.lspec, q, res.pool_ids,
                                IOCounters.zeros(), k=10, s=s)
    n_valid = int((res.pool_ids >= 0).sum())
    assert int(cres.n_loaded) <= n_valid
    assert int(cres.loaded.sum()) == int(cres.n_loaded)
    # counters agree with loads
    vb = spec.lspec.vector_bytes
    assert int(cres.counters.useful_vec_bytes_read) == \
        int(cres.n_loaded) * vb
    assert int(cres.counters.read_requests) == int(cres.n_loaded) * \
        spec.lspec.vector_pages_per_read


def test_casr_saves_vector_loads_vs_full(navis, dataset):
    """On a large pool, CASR must fetch strictly fewer vectors."""
    eng, state = navis
    saved = 0
    for qi in range(5):
        q = dataset["queries"][qi]
        spec = eng.spec
        lut = pq_mod.adc_lut(eng.codec, q)
        from repro.core import search as search_mod
        entries, _ = eng._entries(state, lut)
        res = search_mod.disk_traverse(
            state.store, spec.lspec, lut, state.codes, state.cache,
            IOCounters.zeros(), entries, pool_size=spec.e_pos,
            beam_width=4, max_hops=64)
        cres = casr_mod.casr_rerank(state.store, spec.lspec, q,
                                    res.pool_ids, IOCounters.zeros(),
                                    k=10, s=spec.s_pos)
        n_valid = int((res.pool_ids >= 0).sum())
        saved += n_valid - int(cres.n_loaded)
    assert saved > 0


def test_casr_stop_point_monotone_in_k(navis, dataset):
    eng, state = navis
    q = dataset["queries"][2]
    pool = brute_force_topk(q[None], state.store.vectors,
                            int(state.store.count), 48)[0]
    s5 = int(casr_mod.casr_stop_point(q, state.store.vectors, pool, k=5))
    s20 = int(casr_mod.casr_stop_point(q, state.store.vectors, pool, k=20))
    assert s5 <= s20 + 1        # bigger k needs at least as many loads


def test_calibrate_group_size_returns_positive(navis, dataset):
    eng, state = navis
    pools = brute_force_topk(dataset["queries"][:8], state.store.vectors,
                             int(state.store.count), 48)
    s = casr_mod.calibrate_group_size(KEY, state.store.vectors, pools,
                                      dataset["queries"][:8], k=10)
    assert 1 <= s <= 48


# ---------------------------------------------------------------------------
# insert + entrance
# ---------------------------------------------------------------------------

def test_insert_wires_reciprocal_and_searchable(navis, dataset):
    eng, state = navis
    new = dataset["cents"][3] + 0.01      # a fresh in-distribution vector
    stats, state, _ = eng.insert(state, new)
    new_id = int(state.store.count) - 1
    # the new vertex has edges, and appears in some neighbor's edgelist
    deg = int((state.store.edges[new_id] >= 0).sum())
    assert deg > 0
    incoming = int((state.store.edges[:int(state.store.count)] ==
                    new_id).sum())
    assert incoming > 0
    inv = check_invariants(state.store)
    assert all(bool(v) for v in inv.values())
    # a search for the exact vector finds it
    ids, dists, _, state = eng.search(state, new)
    assert new_id in np.asarray(ids).tolist()


def test_insert_write_volume_decoupled_vs_packed(dataset, shared_bundle):
    """Fig 4(b): packed structural updates co-write neighbor vectors;
    decoupling must cut write bytes."""
    results = {}
    for name in ("odinann", "sel_vec"):
        spec = preset(name, dim=48, r=16, n_max=1600, e_search=40, e_pos=48,
                      pq_m=24, max_hops=64)
        eng = Engine(spec)
        st_ = eng.build(jax.random.PRNGKey(2), dataset["vecs"],
                        shared=shared_bundle)
        newv = insert_stream(jax.random.PRNGKey(9), dataset["cents"], 10)
        stats, st_ = eng.insert_batch(st_, newv)
        results[name] = int(stats.write_bytes.sum())
    assert results["sel_vec"] < results["odinann"], results


def test_entrance_update_properties(navis, dataset):
    eng, state = navis
    ent0 = int(state.ent.count)
    newv = insert_stream(jax.random.PRNGKey(10), dataset["cents"], 15)
    _, state = eng.insert_batch(state, newv)
    ent1 = int(state.ent.count)
    assert ent1 >= ent0          # dynamic entrance may grow
    # main_to_ent is an exact inverse of ids
    ids = np.asarray(state.ent.ids)
    m2e = np.asarray(state.ent.main_to_ent)
    for slot, main in enumerate(ids):
        if main >= 0:
            assert m2e[main] == slot
    # degree cap respected
    deg = (np.asarray(state.ent.edges) >= 0).sum(1)
    assert (deg <= state.ent.r_ent).all()


def test_entrance_update_skipped_above_threshold(dataset, shared_bundle):
    spec = preset("navis", dim=48, r=16, n_max=1600, e_search=40, e_pos=48,
                  pq_m=24, max_hops=64, ent_frac=0.001)  # tiny threshold
    eng = Engine(spec)
    st_ = eng.build(jax.random.PRNGKey(2), dataset["vecs"],
                    shared=shared_bundle)
    ent0 = int(st_.ent.count)
    newv = insert_stream(jax.random.PRNGKey(11), dataset["cents"], 5)
    _, st_ = eng.insert_batch(st_, newv)
    assert int(st_.ent.count) == ent0   # already above 0.1% coverage


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def test_navis_recall(navis, dataset):
    eng, state = navis
    ids, _, _, _ = eng.search_batch(state, dataset["queries"])
    r = float(recall_at_k(ids, dataset["truth"]))
    assert r >= 0.9, r


def test_odinann_recall(odinann, dataset):
    eng, state = odinann
    ids, _, _, _ = eng.search_batch(state, dataset["queries"])
    r = float(recall_at_k(ids, dataset["truth"]))
    assert r >= 0.9, r


def test_delete_removes_from_results(navis, dataset):
    eng, state = navis
    q = dataset["queries"][0]
    ids, _, _, state = eng.search(state, q)
    victim = int(np.asarray(ids)[0])
    state = eng.delete(state, jnp.int32(victim))
    ids2, _, _, state = eng.search(state, q)
    assert victim not in np.asarray(ids2).tolist()


def test_freshdiskann_buffer_and_merge(freshdiskann, dataset):
    eng, state = freshdiskann
    count0 = int(state.store.count)
    newv = insert_stream(jax.random.PRNGKey(12), dataset["cents"], 8)
    stats, state = eng.insert_batch(state, newv)
    # buffered: no storage writes yet, vectors searchable from the buffer
    assert int(stats.write_requests.sum()) == 0
    assert int(state.store.count) == count0
    ids, _, _, state = eng.search(state, newv[0])
    assert (np.asarray(ids) >= state.store.n_max).any()   # buffer hit
    # force a merge
    mstats, state = eng.merge(state)
    assert int(state.store.count) == count0 + 8
    assert int(state.buf_count) == 0
    assert int(mstats.write_requests) > 0                 # stream rewrite
    inv = check_invariants(state.store)
    assert all(bool(v) for v in inv.values())


def test_counter_categories_are_exclusive(navis, dataset):
    eng, state = navis
    c = state.ctr_search
    total = int(c.total_read_bytes())
    parts = (int(c.edge_bytes_read) + int(c.useful_vec_bytes_read) +
             int(c.wasted_vec_bytes_read) + int(c.pad_bytes_read))
    assert total == parts
