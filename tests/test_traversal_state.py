"""O(1)-state traversal: hashed visited sets ≡ bitmap reference, overflow
saturation semantics, entrance seed guard, kernel dispatch contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine, pq as pq_mod
from repro.core import insert as insert_mod
from repro.core import search as search_mod
from repro.core import visited as visited_mod
from repro.core.entrance import EntranceGraph
from repro.core.iomodel import IOCounters
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(21)


def _counters_equal(a: IOCounters, b: IOCounters):
    for f in dataclasses.fields(IOCounters):
        va, vb = int(getattr(a, f.name)), int(getattr(b, f.name))
        assert va == vb, (f.name, va, vb)


@pytest.fixture(scope="module")
def bitmap_twin(navis):
    """Same spec/codec as the session engine, dense-bitmap visited sets.
    Runs against the *same* EngineState, so every op is an apples-to-apples
    comparison (state is engine-independent; only codec + spec matter)."""
    eng, _ = navis
    twin = Engine(eng.spec.with_(visited_impl="bitmap"))
    twin.codec = eng.codec
    twin._sym = eng._sym
    return twin


# ---------------------------------------------------------------------------
# hashed visited sets: unit properties
# ---------------------------------------------------------------------------

def test_hash_set_basics():
    vs = visited_mod.make_hash(16)
    keys = jnp.array([3, 900001, 3, -1, 77], jnp.int32)
    vs = visited_mod.add(vs, keys, jnp.ones(5, bool))
    assert int(vs.count) == 3                     # dup + invalid dropped
    got = visited_mod.contains(vs, jnp.array([3, 900001, 77, 4, -1]))
    assert got.tolist() == [True, True, True, False, False]
    assert int(visited_mod.overflow(vs)) == 0


def test_hash_set_saturates_without_corruption():
    vs = visited_mod.make_hash(2)                 # table of 8
    keys = jnp.arange(50, dtype=jnp.int32)
    vs = visited_mod.add(vs, keys, jnp.ones(50, bool))
    assert int(vs.count) == vs.keys.shape[0]      # full
    assert int(vs.overflow) == 50 - vs.keys.shape[0]
    # every key the table holds still answers membership correctly
    held = np.asarray(vs.keys)
    assert (held >= 0).all()
    assert bool(visited_mod.contains(vs, jnp.asarray(held)).all())


def test_dense_matches_hash_on_random_streams():
    k1, k2 = jax.random.split(KEY)
    keys = jax.random.randint(k1, (200,), 0, 400).astype(jnp.int32)
    mask = jax.random.bernoulli(k2, 0.8, (200,))
    hs = visited_mod.add(visited_mod.make_hash(200), keys, mask)
    ds = visited_mod.add(visited_mod.make_dense(400), keys, mask)
    probe = jnp.arange(400, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(visited_mod.contains(hs, probe)),
        np.asarray(visited_mod.contains(ds, probe)))
    assert int(visited_mod.overflow(hs)) == 0


# ---------------------------------------------------------------------------
# traversal equivalence: hash ≡ bitmap, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("frozen", [False, True])
def test_disk_traverse_hash_matches_bitmap(navis, dataset, frozen):
    eng, state = navis
    spec = eng.spec
    for qi in range(3):
        q = dataset["queries"][qi]
        lut = pq_mod.adc_lut(eng.codec, q)
        entries, _ = eng._entries(state, lut)
        res = {}
        for kind in ("hash", "bitmap"):
            res[kind] = search_mod.disk_traverse(
                state.store, spec.lspec, lut, state.codes, state.cache,
                IOCounters.zeros(), entries, pool_size=spec.e_search,
                beam_width=spec.beam_width, max_hops=64,
                frozen_cache=frozen, visited=kind)
        a, b = res["hash"], res["bitmap"]
        np.testing.assert_array_equal(np.asarray(a.pool_ids),
                                      np.asarray(b.pool_ids))
        np.testing.assert_array_equal(np.asarray(a.pool_dists),
                                      np.asarray(b.pool_dists))
        assert int(a.hops) == int(b.hops)
        _counters_equal(a.counters, b.counters)
        assert int(a.counters.visited_overflow) == 0
        if frozen:
            np.testing.assert_array_equal(np.asarray(a.trace),
                                          np.asarray(b.trace))
            assert int(a.trace_n) == int(b.trace_n)


def test_position_seek_hash_matches_bitmap(navis, dataset):
    eng, state = navis
    spec = eng.spec
    v = dataset["cents"][5] + 0.02
    lut = pq_mod.adc_lut(eng.codec, v)
    entries, _ = eng._entries(state, lut)
    out = {}
    for kind in ("hash", "bitmap"):
        out[kind] = insert_mod.position_seek(
            state.store, spec.lspec, eng.codec, state.codes, state.cache,
            IOCounters.zeros(), v, entries, e_pos=spec.e_pos, k=spec.k,
            s=spec.s_pos, beam_width=spec.beam_width, max_hops=64,
            tombstone=state.tombstone, frozen_cache=True, visited=kind)
    a, b = out["hash"], out["bitmap"]
    np.testing.assert_array_equal(np.asarray(a.nbrs), np.asarray(b.nbrs))
    np.testing.assert_array_equal(np.asarray(a.pool_ids),
                                  np.asarray(b.pool_ids))
    np.testing.assert_array_equal(np.asarray(a.trace), np.asarray(b.trace))
    _counters_equal(a.counters, b.counters)


def test_search_many_hash_matches_bitmap(navis, bitmap_twin, dataset):
    """The PR1 fan-out path: identical ids/dists/counters on both visited
    implementations, run against the same shared snapshot."""
    eng, state = navis
    qs = dataset["queries"][:8]
    ids_h, d_h, stats_h, st_h = eng.search_many(state, qs)
    ids_b, d_b, stats_b, st_b = bitmap_twin.search_many(state, qs)
    np.testing.assert_array_equal(np.asarray(ids_h), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(d_h), np.asarray(d_b))
    _counters_equal(st_h.ctr_search, st_b.ctr_search)
    assert int(st_h.ctr_search.visited_overflow) == 0


def test_insert_many_hash_matches_bitmap(navis, bitmap_twin, dataset):
    """The PR2 fan-out path: identical wave commits and I/O accounting."""
    eng, state = navis
    newv = dataset["cents"][:6] + 0.03
    stats_h, st_h = eng.insert_many(state, newv)
    stats_b, st_b = bitmap_twin.insert_many(state, newv)
    np.testing.assert_array_equal(np.asarray(st_h.store.edges),
                                  np.asarray(st_b.store.edges))
    assert int(st_h.store.count) == int(st_b.store.count)
    for f in stats_h._fields:
        np.testing.assert_array_equal(np.asarray(getattr(stats_h, f)),
                                      np.asarray(getattr(stats_b, f)))
    _counters_equal(st_h.ctr_insert, st_b.ctr_insert)


# ---------------------------------------------------------------------------
# saturation: forced-overflow traversal stays well-formed, counter bumps
# ---------------------------------------------------------------------------

def test_traversal_saturation_counted_and_correct(navis, dataset):
    eng, state = navis
    spec = eng.spec
    q = dataset["queries"][3]
    lut = pq_mod.adc_lut(eng.codec, q)
    entries, _ = eng._entries(state, lut)

    def run(**kw):
        return search_mod.disk_traverse(
            state.store, spec.lspec, lut, state.codes, state.cache,
            IOCounters.zeros(), entries, pool_size=spec.e_search,
            beam_width=spec.beam_width, max_hops=64, **kw)

    base = run(visited="bitmap")
    sat = run(visited="hash", visited_capacity=4)   # table of 8: saturates
    assert int(sat.counters.visited_overflow) > 0
    # results stay well-formed: valid unique ids, ascending distances
    ids = np.asarray(sat.pool_ids)
    live = ids[ids >= 0]
    assert len(live) == len(set(live.tolist()))
    assert (live < int(state.store.count)).all()
    d = np.asarray(sat.pool_dists)
    d = d[np.isfinite(d) & (d < 3e38)]
    assert (np.diff(d) >= 0).all()
    # saturation only re-charges I/O — never reads less than the exact run
    # spent up to the saturation point, and re-expansions burn hops
    assert int(sat.counters.hops) >= int(base.counters.hops) or \
        int(sat.counters.hops) == 64


# ---------------------------------------------------------------------------
# entrance seed guard
# ---------------------------------------------------------------------------

def test_entrance_seed_falls_back_past_dead_slot0(navis, dataset):
    """Regression: deletes can kill entrance slot 0 (the medoid-ish seed)
    and scrub edges pointing at it; the seed must fall back to the first
    live slot instead of starting (and possibly dying) on the corpse."""
    eng, state = navis
    n_max = state.store.n_max
    # dead slot 0 with fully scrubbed edges; slots 1..3 live and wired
    ids = jnp.full((8,), -1, jnp.int32).at[1].set(1).at[2].set(2).at[3].set(3)
    edges = jnp.full((8, 4), -1, jnp.int32)
    edges = edges.at[1, :2].set(jnp.array([2, 3]))
    edges = edges.at[2, :2].set(jnp.array([1, 3]))
    edges = edges.at[3, :2].set(jnp.array([1, 2]))
    m2e = jnp.full((n_max,), -1, jnp.int32)
    m2e = m2e.at[1].set(1).at[2].set(2).at[3].set(3)
    ent = EntranceGraph(ids=ids, edges=edges,
                        count=jnp.asarray(4, jnp.int32), main_to_ent=m2e)
    q = state.store.vectors[2]
    lut = pq_mod.adc_lut(eng.codec, q)
    entries, e_ent, _ = search_mod.entrance_search(
        ent, lut, state.codes, n_entry=2, pool_size=4)
    got = np.asarray(entries)
    assert (got >= 0).any()                      # pre-fix: all -1
    assert set(got[got >= 0].tolist()) <= {1, 2, 3}


def test_delete_entrance_slot0_member_search_survives(navis, dataset):
    eng, state = navis
    vid = int(state.ent.ids[0])
    assert vid >= 0
    st2 = eng.delete(state, jnp.int32(vid))
    assert int(st2.ent.ids[0]) == -1             # slot 0 now dead
    ids, dists, _, st3 = eng.search(st2, dataset["vecs"][vid])
    got = np.asarray(ids)
    assert vid not in got.tolist()
    assert (got >= 0).any()                      # seed fell back, not empty


# ---------------------------------------------------------------------------
# per-query state accounting + kernel dispatch contract
# ---------------------------------------------------------------------------

def test_traversal_state_bytes_flat_in_corpus():
    sizes = (10_000, 100_000, 1_000_000)
    kw = dict(pool_size=100, beam_width=4, max_hops=256, frozen=True)
    hashed = [search_mod.traversal_state_bytes(
        n_max=n, p_max=2 * n, visited="hash", **kw) for n in sizes]
    dense = [search_mod.traversal_state_bytes(
        n_max=n, p_max=2 * n, visited="bitmap", **kw) for n in sizes]
    assert len(set(hashed)) == 1                 # O(1) in n_max
    assert dense[0] < dense[1] < dense[2]        # O(n_max)
    assert hashed[0] < dense[0]


def test_kernel_dispatch_default_is_ref_off_tpu(monkeypatch):
    if jax.default_backend() == "tpu":
        pytest.skip("dispatch resolves to mosaic on TPU")
    monkeypatch.delenv("NAVIS_KERNEL_INTERPRET", raising=False)
    assert ops.kernel_mode() == "ref"
    monkeypatch.setenv("NAVIS_KERNEL_INTERPRET", "1")
    assert ops.kernel_mode() == "interpret"
    monkeypatch.setenv("NAVIS_KERNEL_INTERPRET", "0")
    assert ops.kernel_mode() == "ref"


def test_ops_ref_mode_bit_identical_to_oracles(monkeypatch):
    if jax.default_backend() == "tpu":
        pytest.skip("off-TPU contract")
    monkeypatch.delenv("NAVIS_KERNEL_INTERPRET", raising=False)
    lut = jax.random.uniform(KEY, (16, 256))
    codes = jax.random.randint(KEY, (37, 16), 0, 256).astype(jnp.uint8)
    np.testing.assert_array_equal(np.asarray(ops.adc_distance(lut, codes)),
                                  np.asarray(ref.adc_distance_ref(lut,
                                                                  codes)))
    q = jax.random.normal(KEY, (32,))
    xs = jax.random.normal(jax.random.fold_in(KEY, 1), (21, 32))
    np.testing.assert_array_equal(np.asarray(ops.rerank_l2(q, xs)),
                                  np.asarray(ref.rerank_l2_ref(q, xs)))
    pd = jax.random.uniform(KEY, (9,))
    nd = jax.random.uniform(jax.random.fold_in(KEY, 2), (14,))
    pi = jnp.arange(9, dtype=jnp.int32)
    ni = 100 + jnp.arange(14, dtype=jnp.int32)
    gd, gi = ops.pool_merge(pd, pi, nd, ni)
    wd, wi = ref.pool_merge_ref(pd, pi, nd, ni)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
