"""Cache-policy properties (NAVIS window+frozen, LRU, CLOCK, LFU)."""
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container: seeded shim
    from _prop import given, settings, st

from repro.core import cache as C

KEY = jax.random.PRNGKey(0)
P_MAX = 256

# jitted once: op-by-op dispatch of the cache's many tiny lax ops floods
# the XLA:CPU JIT with one compiled program per op
_access = jax.jit(C.access)
_invalidate = jax.jit(C.invalidate_page)


def _mk(policy, capacity=16):
    return C.init_cache(P_MAX, capacity, policy, KEY)


def _occupancy(st_):
    return int((st_.window_pages >= 0).sum() + (st_.frozen_pages >= 0).sum())


@pytest.mark.parametrize("policy", ["navis", "lru", "clock", "lfu"])
def test_hit_after_access(policy):
    st_ = _mk(policy)
    hit, st_ = _access(st_, jnp.int32(5))
    assert not bool(hit)
    hit, st_ = _access(st_, jnp.int32(5))
    assert bool(hit)


@pytest.mark.parametrize("policy", ["navis", "lru", "clock", "lfu"])
def test_capacity_never_exceeded(policy):
    st_ = _mk(policy, capacity=10)
    for p in range(40):
        _, st_ = _access(st_, jnp.int32(p % 23))
    assert _occupancy(st_) <= 10


def test_none_policy_never_hits():
    st_ = _mk("none")
    for _ in range(3):
        hit, st_ = _access(st_, jnp.int32(1))
        assert not bool(hit)


def test_navis_promotion_needs_two_window_hits():
    st_ = _mk("navis", capacity=20)          # window=2, frozen=18
    _, st_ = _access(st_, jnp.int32(7))     # miss -> window
    assert int(st_.status[7]) == 1           # IN_WINDOW
    _, st_ = _access(st_, jnp.int32(7))     # first window hit -> promoted
    assert int(st_.status[7]) == 2           # IN_FROZEN
    slot = int(st_.slot_of[7])
    assert int(st_.frozen_pages[slot]) == 7


def test_navis_one_off_pages_never_pollute_frozen():
    st_ = _mk("navis", capacity=20)
    for p in range(50, 90):                  # one-off scan
        _, st_ = _access(st_, jnp.int32(p))
    assert int((st_.frozen_pages >= 0).sum()) == 0


def test_lru_evicts_oldest():
    st_ = _mk("lru", capacity=3)
    for p in (1, 2, 3):
        _, st_ = _access(st_, jnp.int32(p))
    _, st_ = _access(st_, jnp.int32(1))     # refresh 1
    _, st_ = _access(st_, jnp.int32(4))     # evicts 2 (oldest)
    hit, st_ = _access(st_, jnp.int32(2))
    assert not bool(hit)
    hit, st_ = _access(st_, jnp.int32(1))
    assert bool(hit)


def test_invalidate_page_drops_entry():
    st_ = _mk("navis", capacity=20)
    _, st_ = _access(st_, jnp.int32(9))
    st_ = _invalidate(st_, jnp.int32(9))
    assert int(st_.status[9]) == 0
    hit, st_ = _access(st_, jnp.int32(9))
    assert not bool(hit)


@settings(max_examples=10, deadline=None)
@given(policy=st.sampled_from(["navis", "lru", "clock", "lfu"]),
       seed=st.integers(0, 999))
def test_status_slot_consistency(policy, seed):
    """status/slot_of tables always agree with the region arrays."""
    st_ = _mk(policy, capacity=8)
    k = jax.random.PRNGKey(seed)
    pages = jax.random.randint(k, (60,), 0, 30)
    for p in pages:
        _, st_ = _access(st_, p.astype(jnp.int32))
    status = jax.device_get(st_.status)
    slot_of = jax.device_get(st_.slot_of)
    window = jax.device_get(st_.window_pages)
    frozen = jax.device_get(st_.frozen_pages)
    for page in range(P_MAX):
        if status[page] == 1:
            assert window[slot_of[page]] == page
        elif status[page] == 2:
            assert frozen[slot_of[page]] == page
    for slot, page in enumerate(window):
        if page >= 0:
            assert status[page] == 1 and slot_of[page] == slot
    for slot, page in enumerate(frozen):
        if page >= 0:
            assert status[page] == 2 and slot_of[page] == slot
