"""Maintenance subsystem (ISSUE 4): tombstone reclamation into the free
list, dead-edge repair, edgelist defrag + cache invalidation, entrance
refresh, and the churn contract — inserts stop dropping once reclaimed
slots exist."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Engine, brute_force_topk, check_invariants, preset,
                        recall_at_k)
from repro.core import cache as cache_mod
from repro.data import insert_stream, query_stream


def _delete_some(eng, state, n, seed=0, forbid=()):
    """Tombstone ``n`` random live vertices; returns (state, victims)."""
    rng = np.random.default_rng(seed)
    pool = np.setdiff1d(np.flatnonzero(np.asarray(state.live_mask)),
                        np.asarray(forbid))
    victims = rng.choice(pool, n, replace=False).astype(np.int32)
    return eng.delete_many(state, jnp.asarray(victims)), victims


# ---------------------------------------------------------------------------
# consolidation pass: repair + reclaim + refresh
# ---------------------------------------------------------------------------

def test_consolidate_reclaims_and_repairs(navis, dataset):
    eng, state = navis
    state, victims = _delete_some(eng, state, 60, seed=1)
    # pre-consolidation: live edgelists do reference the dead vertices
    inv = check_invariants(state.store, state.tombstone)
    assert not bool(inv["no_dead_refs"])

    stats, st2 = eng.consolidate(state)
    inv = check_invariants(st2.store, st2.tombstone)
    assert all(bool(v) for v in inv.values()), inv
    # every tombstoned slot was reclaimed into the free list
    assert int(st2.free_count) == len(victims)
    fl = np.asarray(st2.free_list[:int(st2.free_count)])
    assert sorted(fl.tolist()) == sorted(victims.tolist())
    assert np.asarray(st2.free_mask).sum() == len(victims)
    # reclaimed rows hold no graph state
    assert (np.asarray(st2.store.edges[victims]) == -1).all()
    assert (np.asarray(st2.store.degree[victims]) == 0).all()
    # the entrance graph only references live vertices
    ids = np.asarray(st2.ent.ids)
    assert not np.asarray(st2.tombstone)[ids[ids >= 0]].any()
    # default entries are live
    de = np.asarray(st2.default_entries)
    assert not np.asarray(st2.tombstone)[de].any()


def test_consolidate_charges_maintenance_io(navis, dataset):
    eng, state = navis
    state, _ = _delete_some(eng, state, 40, seed=2)
    ctr0 = state.ctr_maint
    stats, st2 = eng.consolidate(state)
    delta = jax.tree.map(lambda a, b: a - b, st2.ctr_maint, ctr0)
    # the pass reads the sweep + defrag stream and writes repairs + defrag
    assert int(stats.read_requests) > 0
    assert int(stats.write_requests) > 0
    assert int(stats.read_requests) == int(delta.read_requests)
    assert int(stats.write_requests) == int(delta.write_requests)
    assert int(stats.read_bytes) == int(delta.total_read_bytes())
    assert int(stats.write_bytes) == int(delta.total_write_bytes())
    # foreground counters are untouched by maintenance
    for f in ("ctr_search", "ctr_insert"):
        for a, b in zip(jax.tree.leaves(getattr(st2, f)),
                        jax.tree.leaves(getattr(state, f))):
            assert int(a) == int(b)


def test_maintenance_step_is_incremental(navis, dataset):
    eng, state = navis
    state, _ = _delete_some(eng, state, 30, seed=3)
    n_steps = 0
    done = False
    st = dataclasses.replace(state, maint_cursor=jnp.zeros((), jnp.int32))
    while not done:
        st, done = eng.maintenance_step(st)
        n_steps += 1
        assert n_steps < 100
    # sweep blocks + one finalization step
    expect = -(-int(state.store.count) // eng.spec.maint_block) + 1
    assert n_steps == expect
    assert int(st.free_count) == 30
    assert int(st.maint_cursor) == 0          # ready for the next cycle


def test_search_parity_across_consolidation(navis, dataset):
    """Live-vertex search results (ids AND dists) are preserved across a
    consolidation pass: repair only reroutes around tombstoned vertices
    the result mask already hid, defrag only moves pages, and the
    entrance refresh re-seeds traversals that converge to the same
    exact-reranked top-k."""
    eng, state = navis
    state, _ = _delete_some(eng, state, 60, seed=4)
    qs = dataset["queries"]
    ids0, d0, _, state = eng.search_many(state, qs)
    _, st2 = eng.consolidate(state)
    ids1, d1, _, _ = eng.search_many(st2, qs)
    np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_consolidate_invalidates_relocated_pages(navis, dataset):
    eng, state = navis
    # warm the cache so edge pages are resident
    for q in dataset["queries"][:16]:
        _, _, _, state = eng.search(state, q)
    state, _ = _delete_some(eng, state, 40, seed=5)
    before = np.asarray(state.store.edge_page)
    _, st2 = eng.consolidate(state)
    after = np.asarray(st2.store.edge_page)
    moved = before != after
    changed = set(before[moved & (before >= 0)].tolist()) | \
        set(after[moved & (after >= 0)].tolist())
    # the entrance-aware hint re-admits live members' (fresh, post-defrag)
    # pages after the invalidation sweep — those are current, not stale
    ids = np.asarray(st2.ent.ids)
    admitted = set(after[ids[ids >= 0]].tolist())
    status = np.asarray(st2.cache.status)
    for p in changed - admitted:
        assert status[p] == 0, f"stale page {p} still cached"
    assert changed, "consolidation moved nothing?"
    # and the cache survives consistently: a fresh search still works
    ids, _, _, _ = eng.search(st2, dataset["queries"][0])
    assert (np.asarray(ids) >= 0).any()


def test_tombstone_skips_counter(navis, dataset):
    eng, state = navis
    state, _ = _delete_some(eng, state, 80, seed=6)
    ctr0 = int(state.ctr_search.tombstone_skips)
    _, _, _, state = eng.search_many(state, dataset["queries"])
    wasted = int(state.ctr_search.tombstone_skips) - ctr0
    assert wasted > 0               # dead vertices polluted explored pools
    _, st2 = eng.consolidate(state)
    ctr1 = int(st2.ctr_search.tombstone_skips)
    _, _, _, st2 = eng.search_many(st2, dataset["queries"])
    assert int(st2.ctr_search.tombstone_skips) == ctr1   # pools are clean


# ---------------------------------------------------------------------------
# free-list slot reuse (delete → consolidate → insert round trip)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small(dataset):
    """A nearly-full engine: 400 base vectors, 4 slots of headroom."""
    n_base = 400
    eng = Engine(preset("navis", dim=48, r=16, n_max=n_base + 4,
                        e_search=32, e_pos=40, pq_m=24, max_hops=48,
                        cache_capacity_pages=128, buffer_max=32,
                        maint_block=128))
    state = eng.build(jax.random.PRNGKey(3), dataset["vecs"][:n_base],
                      build_block=64, build_e_pos=32)
    return eng, state


def test_tombstoned_slot_reuse_round_trip(small, dataset):
    eng, state = small
    vid = 123
    state = eng.delete(state, jnp.int32(vid))
    _, state = eng.consolidate(state)
    assert int(state.free_count) == 1
    count0 = int(state.store.count)
    newv = dataset["cents"][5] + 0.02
    stats, state, _ = eng.insert(state, newv)
    assert not bool(stats.dropped)
    # the insert landed in the freed slot, not a fresh one
    assert int(state.store.count) == count0
    assert int(state.free_count) == 0
    assert not bool(state.tombstone[vid])
    np.testing.assert_allclose(np.asarray(state.store.vectors[vid]),
                               np.asarray(newv), rtol=1e-6)
    # and it is searchable under its recycled id
    ids, _, _, state = eng.search(state, newv)
    assert vid in np.asarray(ids).tolist()
    inv = check_invariants(state.store, state.tombstone)
    assert all(bool(v) for v in inv.values()), inv


def test_churn_does_not_drop_inserts_at_capacity(small, dataset):
    """The production steady state: at count == n_max, delete + consolidate
    + insert keeps accepting writes — without maintenance every one of
    these inserts would drop."""
    eng, state = small
    n_max = state.store.n_max
    # fill the fresh headroom
    fill = insert_stream(jax.random.PRNGKey(31), dataset["cents"], 4)
    _, state = eng.insert_many(state, fill)
    assert int(state.store.count) == n_max

    for round_ in range(3):
        state, victims = _delete_some(eng, state, 5, seed=40 + round_)
        assert bool(eng.needs_consolidation(state, lookahead=5))
        _, state = eng.consolidate(state)
        wave = insert_stream(jax.random.PRNGKey(50 + round_),
                             dataset["cents"], 5)
        stats, state = eng.insert_many(state, wave)
        assert not np.asarray(stats.dropped).any()
        assert int(state.store.count) == n_max
        assert int(state.live_count) == n_max
    inv = check_invariants(state.store, state.tombstone)
    assert all(bool(v) for v in inv.values()), inv
    # the no-maintenance control: same wave against the full state drops
    state2, _ = _delete_some(eng, state, 5, seed=99)
    wave = insert_stream(jax.random.PRNGKey(60), dataset["cents"], 5)
    stats, _ = eng.insert_many(state2, wave)
    assert np.asarray(stats.dropped).all()


def test_insert_many_draws_from_free_list(navis, dataset):
    eng, state = navis
    state, victims = _delete_some(eng, state, 5, seed=7)
    _, state = eng.consolidate(state)
    count0 = int(state.store.count)
    wave = insert_stream(jax.random.PRNGKey(70), dataset["cents"], 8)
    stats, st2 = eng.insert_many(state, wave)
    assert not np.asarray(stats.dropped).any()
    # five commits reused freed slots, three extended the prefix
    assert int(st2.store.count) == count0 + 3
    assert int(st2.free_count) == 0
    assert not np.asarray(st2.tombstone)[victims].any()
    inv = check_invariants(st2.store, st2.tombstone)
    assert all(bool(v) for v in inv.values()), inv
    # held-out recall against the live set stays healthy
    truth = brute_force_topk(dataset["queries"], st2.store.vectors,
                             st2.live_mask, 10)
    ids, _, _, _ = eng.search_batch(st2, dataset["queries"])
    assert float(recall_at_k(ids, truth)) >= 0.9


def test_needs_consolidation_trigger(navis, dataset):
    eng, state = navis
    assert not bool(eng.needs_consolidation(state))
    frac = eng.spec.consolidate_frac
    n = int(np.ceil(frac * int(state.store.count))) + 2
    state, _ = _delete_some(eng, state, n, seed=8)
    assert bool(eng.needs_consolidation(state))
    _, st2 = eng.consolidate(state)
    assert not bool(eng.needs_consolidation(st2))     # nothing pending
    # capacity-pressure clause: headroom below the upcoming wave size
    headroom = int(st2.store.n_max - st2.store.count) + int(st2.free_count)
    st3, _ = _delete_some(eng, st2, 1, seed=9)
    assert bool(eng.needs_consolidation(st3, lookahead=headroom + 10))
    assert not bool(eng.needs_consolidation(st3, lookahead=1))


# ---------------------------------------------------------------------------
# entrance-promotion cache hint (priority admission)
# ---------------------------------------------------------------------------

def test_priority_admit_pins_into_frozen():
    st_ = cache_mod.init_cache(128, 20, "navis", jax.random.PRNGKey(0))
    st_ = cache_mod.priority_admit(st_, jnp.int32(7))
    assert int(st_.status[7]) == 2                     # IN_FROZEN
    slot = int(st_.slot_of[7])
    assert int(st_.frozen_pages[slot]) == 7
    hit, _ = cache_mod.access(st_, jnp.int32(7))
    assert bool(hit)
    # a page sitting in the window is moved, not duplicated
    st_ = cache_mod.init_cache(128, 20, "navis", jax.random.PRNGKey(0))
    _, st_ = cache_mod.access(st_, jnp.int32(9))       # miss -> window
    st_ = cache_mod.priority_admit(st_, jnp.int32(9))
    assert int(st_.status[9]) == 2
    assert int((st_.window_pages == 9).sum()) == 0
    # single-region policies have no frozen region: no-op
    st_ = cache_mod.init_cache(128, 20, "lru", jax.random.PRNGKey(0))
    st0 = cache_mod.priority_admit(st_, jnp.int32(7))
    for a, b in zip(jax.tree.leaves(st_), jax.tree.leaves(st0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_entrance_promotion_admits_page(navis, dataset):
    """An insert that promotes into the dynamic entrance leaves the new
    vertex's edgelist page resident in the frozen cache region."""
    eng, state = navis
    newv = insert_stream(jax.random.PRNGKey(80), dataset["cents"], 10)
    ent0 = int(state.ent.count)
    for i in range(10):
        _, state, _ = eng.insert(state, newv[i])
        if int(state.ent.count) > ent0:
            new_id = int(state.ent.ids[int(state.ent.count) - 1])
            page = int(state.store.edge_page[new_id])
            assert int(state.cache.status[page]) == 2  # IN_FROZEN
            return
    pytest.skip("entrance saturated before any promotion fired")
