"""Offline fallback for ``hypothesis``: seeded-parametrize property tests.

This container has no network, so ``pip install hypothesis`` is not an
option.  The test modules do::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _prop import given, settings, st

and get a miniature, deterministic stand-in: ``given`` draws
``max_examples`` example tuples from the strategies with a PRNG seeded on
the test name and expands them through ``pytest.mark.parametrize``.  No
shrinking, no adaptive search — just reproducible randomized coverage, so
the suite collects and runs everywhere.  When real hypothesis is
installed it wins.

Only the strategy surface this repo uses is implemented
(``sampled_from``, ``integers``, ``floats``, ``booleans``).
"""
from __future__ import annotations

import functools
import random
import zlib

import pytest

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw, label):
        self._draw = draw
        self._label = label

    def example(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):
        return f"st.{self._label}"


class _Strategies:
    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))],
                         f"sampled_from({seq!r})")

    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         f"floats({min_value}, {max_value})")

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5, "booleans()")


st = _Strategies()


def _materialize(fn, strats, n):
    """Expand ``fn`` into a parametrized test with ``n`` seeded draws."""
    names = list(strats)                      # keyword order = declared order
    rng = random.Random(zlib.crc32(fn.__name__.encode()))
    rows = [tuple(strats[k].example(rng) for k in names) for _ in range(n)]

    @functools.wraps(fn)
    def run(*args, **kwargs):
        return fn(*args, **kwargs)

    run._prop_fn = fn
    run._prop_strats = strats
    return pytest.mark.parametrize(",".join(names), rows)(run)


def given(**strats):
    def deco(fn):
        # honour a settings() applied *below* given (hypothesis allows
        # either stacking order)
        n = getattr(fn, "_prop_max_examples", DEFAULT_MAX_EXAMPLES)
        return _materialize(fn, strats, n)
    return deco


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    """Either stacking order works: above ``given`` re-draws with the
    requested count; below it, the count is stashed for given to pick up."""
    def deco(fn):
        if hasattr(fn, "_prop_strats"):
            return _materialize(fn._prop_fn, fn._prop_strats, max_examples)
        fn._prop_max_examples = max_examples
        return fn
    return deco
