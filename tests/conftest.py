"""Shared fixtures: one small clustered dataset + pre-built engines.

Engine builds are the expensive part of the suite, so the graph bundle
(codec + codes + edges) is built once and re-paged per engine config —
the same sharing the benchmarks use.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import Engine, preset, brute_force_topk
from repro.data import make_clustered, query_stream


N, DIM, R = 1200, 48, 16
N_EXTRA = 400            # insert headroom


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """XLA:CPU's LLVM ORC JIT exhausts its dylib symbol space after a few
    hundred distinct compilations in one process ("Failed to materialize
    symbols"); dropping executables between modules keeps the whole suite
    in one pytest invocation (re-tracing is cheap next to the engine
    builds, which live outside the jit cache as session fixtures)."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def dataset():
    key = jax.random.PRNGKey(0)
    vecs, assign, cents = make_clustered(key, N, DIM, n_clusters=12,
                                         scale=3.0, noise=1.0)
    queries = query_stream(jax.random.PRNGKey(1), cents, 40)
    truth = brute_force_topk(queries, vecs, N, 10)
    return dict(vecs=vecs, cents=cents, queries=queries, truth=truth)


def _spec(name):
    return preset(name, dim=DIM, r=R, n_max=N + N_EXTRA, e_search=40,
                  e_pos=48, pq_m=24, cache_capacity_pages=256, max_hops=64,
                  buffer_max=128)


@pytest.fixture(scope="session")
def navis(dataset):
    eng = Engine(_spec("navis"))
    state = eng.build(jax.random.PRNGKey(2), dataset["vecs"],
                      build_block=64, build_e_pos=32)
    return eng, state


@pytest.fixture(scope="session")
def shared_bundle(navis):
    eng, state = navis
    return eng.bundle(state)


@pytest.fixture(scope="session")
def odinann(dataset, shared_bundle):
    eng = Engine(_spec("odinann"))
    state = eng.build(jax.random.PRNGKey(2), dataset["vecs"],
                      shared=shared_bundle)
    return eng, state


@pytest.fixture(scope="session")
def freshdiskann(dataset, shared_bundle):
    eng = Engine(_spec("freshdiskann"))
    state = eng.build(jax.random.PRNGKey(2), dataset["vecs"],
                      shared=shared_bundle)
    return eng, state
