"""Data pipeline, checkpointing, optimizers, train-step substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import TokenStream, insert_stream, make_clustered
from repro.models import transformer as T
from repro import configs as C
from repro.train.optimizer import (adafactor, adamw, clip_by_global_norm,
                                   cosine_schedule)
from repro.train.train_step import init_opt_state, make_train_step


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_token_stream_deterministic():
    s = TokenStream(vocab_size=101, seq_len=16, batch=4, seed=3)
    a = s.make_batch(5)["tokens"]
    b = s.make_batch(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = s.make_batch(6)["tokens"]
    assert not np.array_equal(a, c)
    d = s.make_batch(5, shard=1)["tokens"]
    assert not np.array_equal(a, d)
    assert int(a.max()) < 101 and int(a.min()) >= 0


def test_clustered_corpus_shapes():
    v, a, c = make_clustered(jax.random.PRNGKey(0), 200, 16, n_clusters=4)
    assert v.shape == (200, 16) and c.shape == (4, 16)
    drift0 = insert_stream(jax.random.PRNGKey(1), c, 50, drift=0.0)
    assert drift0.shape == (50, 16)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": [jnp.float32(1.5), jnp.int32(7)],
            "c": {"d": jnp.ones((4,), jnp.int8)}}
    ckpt.save(tmp_path, 3, tree)
    step, out = ckpt.load_latest(tmp_path, tree)
    assert step == 3
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32)
                                      if x.dtype == jnp.bfloat16 else x,
                                      np.asarray(y, np.float32)
                                      if y.dtype == jnp.bfloat16 else y)


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    dirs = sorted(d.name for d in tmp_path.iterdir() if d.is_dir())
    assert dirs == ["step_00000004", "step_00000005"]
    assert ckpt.latest_step(tmp_path) == 5


def test_checkpoint_atomic_torn_commit(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    ckpt.save(tmp_path, 1, tree)
    # simulate a torn commit: LATEST points at a missing dir
    (tmp_path / "LATEST").write_text("step_00000099")
    assert ckpt.latest_step(tmp_path) is None


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    opt = adamw(lr=0.1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for i in range(60):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        updates, state = opt.update(grads, state, params, jnp.int32(i))
        params = jax.tree.map(lambda p, u: p + u, params, updates)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adafactor_factored_state_shapes():
    opt = adafactor(lr=0.05)
    params = {"m": jnp.ones((8, 4)), "v": jnp.ones((5,))}
    st = opt.init(params)
    # state is a list aligned with the flattened param order (m, v)
    assert st["f"][0]["vr"].shape == (8,)
    assert st["f"][0]["vc"].shape == (4,)
    assert st["f"][1]["v"].shape == (5,)
    grads = jax.tree.map(jnp.ones_like, params)
    updates, st = opt.update(grads, st, params, jnp.int32(0))
    assert updates["m"].shape == (8, 4)
    # two steps strictly shrink a quadratic's params
    p2 = jax.tree.map(lambda p, u: p + u, params, updates)
    assert float(jnp.abs(p2["m"]).mean()) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) < 0.01


# ---------------------------------------------------------------------------
# train step: microbatching + grad compression
# ---------------------------------------------------------------------------

def test_microbatch_equals_full_batch():
    cfg = C.get_arch("qwen2-0.5b").smoke
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = adamw(lr=1e-3, state_dtype="float32")
    tokens = jax.random.randint(key, (4, 16), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}

    outs = {}
    for mb in (1, 2):
        step = make_train_step(cfg, opt, microbatches=mb)
        p, s, m = step(params, opt.init(params), batch, jnp.int32(0))
        outs[mb] = (m["loss"], p)
    np.testing.assert_allclose(float(outs[1][0]), float(outs[2][0]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(outs[1][1]),
                    jax.tree.leaves(outs[2][1])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_grad_compression_error_feedback():
    cfg = C.get_arch("qwen2-0.5b").smoke
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = adamw(lr=1e-3)
    opt_state = init_opt_state(cfg, opt, params, grad_compression=True)
    step = make_train_step(cfg, opt, grad_compression=True)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size, jnp.int32)
    losses = []
    for i in range(3):
        params, opt_state, m = step(params, opt_state, {"tokens": tokens},
                                    jnp.int32(i))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    # error-feedback residuals stay bounded by one bf16 ulp scale
    errs = jax.tree.leaves(opt_state["grad_err"])
    assert all(bool(jnp.isfinite(e).all()) for e in errs)
