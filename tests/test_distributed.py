"""Multi-device GVS: shard_map search/insert on 8 fake CPU devices.

Device count is locked at first jax init, so this runs in a subprocess
with XLA_FLAGS set (the same pattern as launch/dryrun.py) — never set the
flag in this process.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.core import Engine, preset, brute_force_topk, recall_at_k
    from repro.core import distributed as dist
    from repro.data import make_clustered, query_stream

    key = jax.random.PRNGKey(0)
    N, D = 1024, 32
    vecs, _, cents = make_clustered(key, N, D, n_clusters=8, noise=1.0)
    queries = query_stream(jax.random.PRNGKey(1), cents, 16)

    n_per = N // 8 + 16
    # tiny 128-vector shards: a 1% entrance sample is 1-2 vertices and
    # mis-seeds the traversal — use 10% (13 entries) and a wider pool
    spec = preset("navis", dim=D, r=12, n_max=n_per, e_search=32,
                  e_pos=40, pq_m=16, cache_capacity_pages=64, max_hops=48,
                  buffer_max=32, ent_frac=0.10)
    eng = Engine(spec)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    sstate = dist.build_sharded_state(eng, jax.random.PRNGKey(2), vecs, 8)
    fn = dist.make_sharded_search(eng, mesh, n_per=N // 8, n_queries=16)
    with mesh:
        ids, dists, sstate = fn(sstate, queries)
    truth = brute_force_topk(queries, vecs, N, 10)
    # globalised ids from range-sharding: shard s owns [s*per, (s+1)*per)
    recall = float(recall_at_k(ids, truth))

    ins = dist.make_sharded_insert(eng, mesh, bucket=4)
    routed, valid = dist.route_inserts(vecs[:8] + 0.01, jnp.arange(8), 8, 4)
    with mesh:
        sstate = ins(sstate, routed, valid)
    counts = [int(c) for c in sstate.store.count]
    print(json.dumps({"recall": recall, "counts": counts,
                      "devices": jax.device_count()}))
""")


_MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import layers as L
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4, 2), ("data", "model"))
    B, S, D, E, F, K = 8, 4, 16, 8, 32, 2
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    params = {
        "router": jax.random.normal(jax.random.fold_in(key, 1), (D, E)) * 0.1,
        "up": jax.random.normal(jax.random.fold_in(key, 2), (E, D, F)) * 0.1,
        "gate": jax.random.normal(jax.random.fold_in(key, 3), (E, D, F)) * 0.1,
        "down": jax.random.normal(jax.random.fold_in(key, 4), (E, F, D)) * 0.1,
    }
    outs = {}
    with mesh:
        for name, gather in (("gather", True), ("two_d", False)):
            rules = L.ShardingRules(batch="data", tensor="model",
                                    fsdp="data", moe_gather_weights=gather)
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            ps = jax.tree.map(lambda w: jax.device_put(
                w, NamedSharding(mesh, P("model", "data", None))
                if w.ndim == 3 else NamedSharding(mesh, P())), params)
            fn = jax.jit(lambda p, xx, r=rules: L.moe_block(
                p, xx, n_experts=E, top_k=K, capacity_factor=8.0,
                activation="silu", glu=True, mesh=mesh, rules=r))
            outs[name] = np.asarray(fn(ps, xs))
    err = float(np.abs(outs["gather"] - outs["two_d"]).max())
    rel = err / max(float(np.abs(outs["gather"]).max()), 1e-9)
    print(json.dumps({"rel_err": rel}))
""")


@pytest.mark.slow
def test_moe_2d_matches_gather_8dev():
    """The decode-path 2-D expert compute must equal the training gather
    path (capacity set high enough that no tokens drop either way)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _MOE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["rel_err"] < 1e-4, res


@pytest.mark.slow
def test_sharded_search_insert_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["devices"] == 8
    # 8 independent 128-vector shards searched with a global merge:
    # recall is bounded by per-shard graph quality on 128 points
    assert res["recall"] >= 0.75, res
    assert sum(res["counts"]) == 1024 + 8
