"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container: seeded shim
    from _prop import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.pq_adc import adc_distance_pallas
from repro.kernels.rerank_l2 import rerank_l2_pallas
from repro.kernels.topk_pool import pool_merge_pallas

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# pq_adc
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [8, 32, 128])
@pytest.mark.parametrize("b", [1, 100, 257, 512])
def test_adc_shapes(m, b):
    lut = jax.random.uniform(KEY, (m, 256))
    codes = jax.random.randint(KEY, (b, m), 0, 256).astype(jnp.uint8)
    got = ops.adc_distance(lut, codes)
    np.testing.assert_allclose(got, ref.adc_distance_ref(lut, codes),
                               rtol=1e-5)


@pytest.mark.parametrize("block_b", [32, 128, 512])
def test_adc_block_sweep(block_b):
    lut = jax.random.uniform(KEY, (16, 256))
    codes = jax.random.randint(KEY, (300, 16), 0, 256).astype(jnp.uint8)
    got = adc_distance_pallas(lut, codes, block_b=block_b, interpret=True)
    np.testing.assert_allclose(got, ref.adc_distance_ref(lut, codes),
                               rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=st.sampled_from([4, 16, 64]), b=st.integers(1, 80),
       seed=st.integers(0, 2 ** 16))
def test_adc_hypothesis(m, b, seed):
    k = jax.random.PRNGKey(seed)
    lut = jax.random.uniform(k, (m, 256), minval=0.0, maxval=100.0)
    codes = jax.random.randint(k, (b, m), 0, 256).astype(jnp.uint8)
    got = adc_distance_pallas(lut, codes, interpret=True)
    np.testing.assert_allclose(got, ref.adc_distance_ref(lut, codes),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# rerank_l2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [32, 96, 768])
@pytest.mark.parametrize("p,group", [(1, 1), (40, 4), (100, 8), (99, 16)])
def test_rerank_shapes(d, p, group):
    q = jax.random.normal(KEY, (d,))
    xs = jax.random.normal(jax.random.fold_in(KEY, 1), (p, d))
    got = ops.rerank_l2(q, xs, group=group)
    np.testing.assert_allclose(got, ref.rerank_l2_ref(q, xs), rtol=2e-4,
                               atol=2e-3)


def test_rerank_dtype_bf16_inputs():
    q = jax.random.normal(KEY, (64,)).astype(jnp.bfloat16)
    xs = jax.random.normal(KEY, (33, 64)).astype(jnp.bfloat16)
    got = ops.rerank_l2(q, xs, group=8)
    want = ref.rerank_l2_ref(q, xs)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=1e-1)


@settings(max_examples=15, deadline=None)
@given(p=st.integers(1, 60), d=st.sampled_from([8, 64, 256]),
       group=st.sampled_from([1, 4, 8]), seed=st.integers(0, 2 ** 16))
def test_rerank_hypothesis(p, d, group, seed):
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (d,))
    xs = jax.random.normal(jax.random.fold_in(k, 1), (p, d))
    got = rerank_l2_pallas(q, xs, group=group, interpret=True)
    np.testing.assert_allclose(got, ref.rerank_l2_ref(q, xs), rtol=2e-4,
                               atol=2e-3)


def test_rerank_self_distance_zero():
    xs = jax.random.normal(KEY, (5, 32))
    got = ops.rerank_l2(xs[2], xs)
    assert float(got[2]) < 1e-4


# ---------------------------------------------------------------------------
# topk_pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,q", [(10, 10), (40, 64), (100, 300)])
def test_merge_shapes(p, q):
    pd = jax.random.uniform(KEY, (p,))
    nd = jax.random.uniform(jax.random.fold_in(KEY, 3), (q,))
    pi = jnp.arange(p, dtype=jnp.int32)
    ni = 10_000 + jnp.arange(q, dtype=jnp.int32)
    gd, gi = ops.pool_merge(pd, pi, nd, ni)
    wd, wi = ref.pool_merge_ref(pd, pi, nd, ni)
    np.testing.assert_allclose(gd, wd, rtol=1e-6)
    np.testing.assert_array_equal(gi, wi)


def test_merge_with_inf_padding():
    INF = jnp.float32(3.4e38)
    pd = jnp.array([1.0, 2.0, INF, INF])
    pi = jnp.array([5, 6, -1, -1], jnp.int32)
    nd = jnp.array([0.5, 3.0])
    ni = jnp.array([7, 8], jnp.int32)
    gd, gi = ops.pool_merge(pd, pi, nd, ni)
    np.testing.assert_array_equal(gi, [7, 5, 6, 8])


@settings(max_examples=15, deadline=None)
@given(p=st.integers(2, 50), q=st.integers(1, 80),
       seed=st.integers(0, 2 ** 16))
def test_merge_hypothesis(p, q, seed):
    k = jax.random.PRNGKey(seed)
    pd = jax.random.uniform(k, (p,))
    nd = jax.random.uniform(jax.random.fold_in(k, 1), (q,))
    pi = jnp.arange(p, dtype=jnp.int32)
    ni = 1000 + jnp.arange(q, dtype=jnp.int32)
    gd, gi = pool_merge_pallas(pd, pi, nd, ni, interpret=True)
    wd, wi = ref.pool_merge_ref(pd, pi, nd, ni)
    np.testing.assert_allclose(gd, wd, rtol=1e-6)
    np.testing.assert_array_equal(gi, wi)
    # result sorted ascending
    assert bool(jnp.all(jnp.diff(gd) >= 0))
