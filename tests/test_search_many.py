"""Batch-parallel search fan-out: equivalence with the sequential scan,
counter-sum invariants, trace replay, and the buffered-insert overflow
regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine, IOCounters, preset
from repro.core import cache as cache_mod
from repro.core import casr as casr_mod
from repro.core.iomodel import sum_counters


# ---------------------------------------------------------------------------
# search_many vs search_batch
# ---------------------------------------------------------------------------

def _batch(dataset, n=12):
    return dataset["queries"][:n]


@pytest.mark.parametrize("fixture", ["navis", "odinann", "freshdiskann"])
def test_search_many_matches_sequential(fixture, dataset, request):
    """vmapped fan-out returns identical top-k (ids AND distances) to the
    lax.scan state-threading path on a shared snapshot — the cache affects
    only I/O charging, never results."""
    eng, state = request.getfixturevalue(fixture)
    qs = _batch(dataset)
    ids_s, d_s, _, _ = eng.search_batch(state, qs)
    ids_m, d_m, _, _ = eng.search_many(state, qs)
    np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_m))
    np.testing.assert_allclose(np.asarray(d_s), np.asarray(d_m))


def test_search_many_counter_sum_invariant(navis, dataset):
    """The engine's cumulative search counters advance by exactly the sum
    of the per-query deltas the fan-out reports."""
    eng, state = navis
    qs = _batch(dataset)
    _, _, stats, state2 = eng.search_many(state, qs)
    delta = jax.tree.map(lambda a, b: a - b,
                         state2.ctr_search, state.ctr_search)
    assert int(np.asarray(stats.read_requests).sum()) == \
        int(delta.read_requests)
    assert int(np.asarray(stats.read_bytes).sum()) == \
        int(delta.total_read_bytes())
    assert int(np.asarray(stats.cache_hits).sum()) == int(delta.cache_hits)
    assert int(np.asarray(stats.cache_misses).sum()) == \
        int(delta.cache_misses)


def test_search_many_batch1_cache_identical(navis, dataset):
    """Replaying a single query's trace onto the snapshot it was recorded
    against reproduces the sequential cache state bit-for-bit (same access
    sequence, same order — including the eviction PRNG key)."""
    eng, state = navis
    q = dataset["queries"][:1]
    _, _, _, st_seq = eng.search_batch(state, q)
    _, _, _, st_par = eng.search_many(state, q)
    for leaf_a, leaf_b in zip(jax.tree.leaves(st_seq.cache),
                              jax.tree.leaves(st_par.cache)):
        np.testing.assert_array_equal(np.asarray(leaf_a),
                                      np.asarray(leaf_b))


def test_search_many_warms_shared_cache(navis, dataset):
    """Trace replay actually feeds the shared cache: a second identical
    wave sees strictly more hits than the first (cold snapshot)."""
    eng, state = navis
    qs = _batch(dataset, 8)
    _, _, stats1, state2 = eng.search_many(state, qs)
    _, _, stats2, _ = eng.search_many(state2, qs)
    h1 = int(np.asarray(stats1.cache_hits).sum())
    h2 = int(np.asarray(stats2.cache_hits).sum())
    assert h2 > h1, (h1, h2)


# ---------------------------------------------------------------------------
# cache: pure lookup + trace replay primitives
# ---------------------------------------------------------------------------

def test_lookup_is_pure_and_matches_access():
    st = cache_mod.init_cache(64, 8, "lru", jax.random.PRNGKey(0))
    _, st = cache_mod.access(st, jnp.int32(3))
    before = jax.tree.leaves(st)
    assert bool(cache_mod.lookup(st, jnp.int32(3)))
    assert not bool(cache_mod.lookup(st, jnp.int32(4)))
    for a, b in zip(before, jax.tree.leaves(st)):      # no mutation
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_apply_trace_equals_sequential_access():
    pages = [5, 9, 5, 2, 9, 5]
    st0 = cache_mod.init_cache(64, 8, "navis", jax.random.PRNGKey(1))
    st_seq, hits_seq = st0, 0
    for p in pages:
        h, st_seq = cache_mod.access(st_seq, jnp.int32(p))
        hits_seq += int(h)
    trace = jnp.asarray(pages + [-1, -1], jnp.int32)   # -1 padding skipped
    hits_rep, st_rep = cache_mod.apply_trace(st0, trace)
    assert int(hits_rep) == hits_seq
    for a, b in zip(jax.tree.leaves(st_seq), jax.tree.leaves(st_rep)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# casr_rerank_many
# ---------------------------------------------------------------------------

def test_casr_rerank_many_matches_single(navis, dataset):
    eng, state = navis
    spec = eng.spec
    qs = dataset["queries"][:4]
    # PQ-sorted pools from the frozen traversal path
    ids, dists, _, _ = eng.search_batch(state, qs)
    pools = jnp.pad(ids, ((0, 0), (0, spec.e_search - ids.shape[1])),
                    constant_values=-1)
    many = casr_mod.casr_rerank_many(state.store, spec.lspec, qs, pools,
                                     IOCounters.zeros(), k=spec.k,
                                     s=spec.s_search)
    for i in range(qs.shape[0]):
        one = casr_mod.casr_rerank(state.store, spec.lspec, qs[i],
                                   pools[i], IOCounters.zeros(),
                                   k=spec.k, s=spec.s_search)
        np.testing.assert_array_equal(np.asarray(many.topk_ids[i]),
                                      np.asarray(one.topk_ids))
        np.testing.assert_allclose(np.asarray(many.topk_d[i]),
                                   np.asarray(one.topk_d))
    total = sum_counters(many.counters)
    assert int(total.read_requests) >= qs.shape[0]     # ≥1 group each


# ---------------------------------------------------------------------------
# buffered-insert overflow regression
# ---------------------------------------------------------------------------

def test_buffered_insert_saturates_at_capacity(dataset):
    """Past buffer_max the insert is dropped: buf_count saturates instead
    of growing unbounded (which corrupted the _merge_buffer_hits validity
    mask and needs_merge), and earlier buffered vectors stay intact."""
    cap = 8
    eng = Engine(preset("freshdiskann", dim=dataset["vecs"].shape[1],
                        r=16, n_max=1300, pq_m=24, e_search=24, e_pos=32,
                        max_hops=48, buffer_max=cap,
                        buffer_frac=1.0))       # merge never auto-triggers
    state = eng.build(jax.random.PRNGKey(0), dataset["vecs"][:1200],
                      build_block=64, build_e_pos=32)
    vnew = dataset["vecs"][:cap + 5] + 0.01
    for i in range(cap + 5):
        _, state, _ = eng._insert_buffered(state, vnew[i])
    assert int(state.buf_count) == cap
    # the first cap vectors are exactly what the buffer holds
    np.testing.assert_allclose(np.asarray(state.buf_vecs),
                               np.asarray(vnew[:cap]), rtol=1e-6)
    # buffer-hit merge still sees a consistent validity mask: searching
    # for buffered vector 0 surfaces its virtual id (n_max + slot)
    ids, dists, _, _ = eng.search(state, vnew[0])
    assert int(state.store.n_max) + 0 in np.asarray(ids).tolist()
