"""Batch-parallel insert fan-out: two-phase ``insert_many`` vs the
sequential scan (graph invariants, recall parity, counter sums, cache
merge), conflict-aware commit primitives, and the insert/delete
correctness regressions (capacity guard, idempotent delete, entrance
edge scrub)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container: seeded shim
    from _prop import given, settings, st

from repro.core import (Engine, IOCounters, brute_force_topk,
                        check_invariants, preset, recall_at_k)
from repro.core import insert as insert_mod
from repro.core import pq as pq_mod
from repro.core.layout import LayoutSpec, empty_store, assign_initial_pages
from repro.data import insert_stream, query_stream


def _wave(dataset, n, seed=7, drift=0.2):
    return insert_stream(jax.random.PRNGKey(seed), dataset["cents"], n,
                         drift=drift)


def _recall(eng, state, queries, truth):
    ids, _, _, _ = eng.search_batch(state, queries)
    return float(recall_at_k(ids, truth))


def _assert_graph_well_formed(state):
    inv = check_invariants(state.store)
    assert all(bool(v) for v in inv.values()), inv
    n = int(state.store.count)
    edges = np.asarray(state.store.edges[:n])
    live = edges[edges >= 0]
    assert (live < n).all()                      # every edge targets a live id


# ---------------------------------------------------------------------------
# insert_many ≡ insert_batch (property-style: seeded waves)
# ---------------------------------------------------------------------------

@settings(max_examples=4)
@given(seed=st.integers(0, 2 ** 20), drift=st.floats(0.0, 0.5))
def test_insert_many_matches_batch_invariants(navis, dataset, seed, drift):
    """Same wave through the fan-out and the scan: identical final count,
    well-formed graph (no self loops, degree ≤ R, all edges live), and
    held-out search recall within tolerance of the sequential graph."""
    eng, state = navis
    newv = insert_stream(jax.random.PRNGKey(seed), dataset["cents"], 12,
                         drift=drift)
    _, st_m = eng.insert_many(state, newv)
    _, st_s = eng.insert_batch(state, newv)

    assert int(st_m.store.count) == int(st_s.store.count)
    _assert_graph_well_formed(st_m)
    _assert_graph_well_formed(st_s)

    qs = dataset["queries"]
    truth = brute_force_topk(qs, st_s.store.vectors,
                             int(st_s.store.count), 10)
    r_m = _recall(eng, st_m, qs, truth)
    r_s = _recall(eng, st_s, qs, truth)
    assert r_m >= r_s - 0.05, (r_m, r_s)


def test_insert_many_single_insert_matches_sequential(navis, dataset):
    """A wave of one has no conflicts: the merged cache is bit-identical
    to the sequential insert's (same trace, same replay order, same
    eviction hints) and the new vertex gets the same neighbor set."""
    eng, state = navis
    one = _wave(dataset, 1)
    _, st_m = eng.insert_many(state, one)
    _, st_s = eng.insert_batch(state, one)
    assert int(st_m.store.count) == int(st_s.store.count)
    for a, b in zip(jax.tree.leaves(st_m.cache),
                    jax.tree.leaves(st_s.cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    new_id = int(state.store.count)
    e_m = sorted(np.asarray(st_m.store.edges[new_id]).tolist())
    e_s = sorted(np.asarray(st_s.store.edges[new_id]).tolist())
    assert e_m == e_s


def test_insert_many_counter_sum_invariant(navis, dataset):
    """The engine's cumulative insert counters advance by exactly the sum
    of the per-insert deltas the fan-out reports — pages are charged once
    per insert (the per-seek page buffer dedupes within an insert) and
    RMW re-reads once per dirty page per commit."""
    eng, state = navis
    newv = _wave(dataset, 10)
    stats, state2 = eng.insert_many(state, newv)
    delta = jax.tree.map(lambda a, b: a - b,
                         state2.ctr_insert, state.ctr_insert)
    assert int(np.asarray(stats.read_requests).sum()) == \
        int(delta.read_requests)
    assert int(np.asarray(stats.write_requests).sum()) == \
        int(delta.write_requests)
    assert int(np.asarray(stats.read_bytes).sum()) == \
        int(delta.total_read_bytes())
    assert int(np.asarray(stats.write_bytes).sum()) == \
        int(delta.total_write_bytes())
    assert int(np.asarray(stats.cache_hits).sum()) == int(delta.cache_hits)
    assert int(np.asarray(stats.cache_misses).sum()) == \
        int(delta.cache_misses)
    assert not np.asarray(stats.dropped).any()


def test_insert_many_replays_traces_into_shared_cache(navis, dataset):
    """Phase-① traces feed the merged cache: a search wave immediately
    after an insert wave sees cache hits on the pages the seeks read."""
    eng, state = navis
    _, state2 = eng.insert_many(state, _wave(dataset, 8))
    _, _, stats, _ = eng.search_many(state2, dataset["queries"][:8])
    assert int(np.asarray(stats.cache_hits).sum()) > 0


def test_insert_many_valid_mask_skips_padding(navis, dataset):
    """Padding lanes (sharded buckets) charge no I/O and commit nothing."""
    eng, state = navis
    newv = _wave(dataset, 8)
    ok = jnp.arange(8) < 5
    stats, st2 = jax.jit(eng._insert_many)(state, newv, ok)
    assert int(st2.store.count) == int(state.store.count) + 5
    rr = np.asarray(stats.read_requests)
    assert (rr[:5] > 0).all() and (rr[5:] == 0).all()
    assert not np.asarray(stats.dropped).any()
    _assert_graph_well_formed(st2)


# ---------------------------------------------------------------------------
# conflict-aware commit primitives
# ---------------------------------------------------------------------------

def _tiny_codec(key, dim=8, m=4, n=64):
    vecs = jax.random.normal(key, (n, dim))
    codec = pq_mod.train_pq(key, vecs, m)
    return codec, pq_mod.encode(codec, vecs), pq_mod.sym_tables(codec)


def test_revalidate_neighbors_drops_and_reprunes():
    codec, codes, sym = _tiny_codec(jax.random.PRNGKey(0))
    tomb = jnp.zeros((64,), bool).at[5].set(True)
    new_id = jnp.int32(60)
    nbrs = jnp.asarray([3, 5, 3, 60, 7, -1], jnp.int32)
    out = insert_mod.revalidate_neighbors(nbrs, new_id, codes[60], codes,
                                          sym, tomb)
    kept = np.asarray(out)
    live = kept[kept >= 0].tolist()
    # tombstoned 5, duplicate 3, self 60 and padding are gone
    assert sorted(live) == [3, 7]
    # survivors are ordered by symmetric-PQ distance to the new vertex
    d = np.asarray(pq_mod.sym_distance(sym, codes[60], codes[jnp.asarray(
        live)]))
    assert (np.diff(d) >= 0).all()
    # valid picks land at the front, padding at the tail
    assert (kept[2:] == -1).all()


def test_charge_rmw_rereads_counts_unique_dirty_pages():
    spec = LayoutSpec(kind="decoupled", dim=8, r=96)   # 10 edgelists/page
    store = assign_initial_pages(empty_store(64, 8, 96), spec)
    store_pages = np.asarray(store.edge_page)
    nbrs = jnp.asarray([0, 1, 60, -1], jnp.int32)
    # vertices 0 and 1 share an edge page; vertex 60 lives elsewhere
    assert store_pages[0] == store_pages[1] != store_pages[60]
    dirty = jnp.zeros_like(store.page_live, dtype=bool)
    dirty = dirty.at[store_pages[0]].set(True)
    ctr, n = insert_mod.charge_rmw_rereads(IOCounters.zeros(), spec, store,
                                           nbrs, dirty)
    assert int(n) == 1                        # one distinct dirty page
    assert int(ctr.read_requests) == 1
    assert int(ctr.edge_bytes_read) > 0
    # nothing dirty -> nothing charged
    ctr0, n0 = insert_mod.charge_rmw_rereads(
        IOCounters.zeros(), spec, store, nbrs,
        jnp.zeros_like(store.page_live, dtype=bool))
    assert int(n0) == 0 and int(ctr0.read_requests) == 0


def test_mark_dirty_pages_tracks_commit_writes():
    spec = LayoutSpec(kind="decoupled", dim=8, r=96)
    store = assign_initial_pages(empty_store(64, 8, 96), spec)
    dirty = jnp.zeros_like(store.page_live, dtype=bool)
    nbrs = jnp.asarray([2, 9, -1, -1], jnp.int32)
    modified = jnp.asarray([True, False, False, False])
    dirty = insert_mod.mark_dirty_pages(dirty, store, jnp.int32(30), nbrs,
                                        modified)
    d = np.asarray(dirty)
    assert d[np.asarray(store.edge_page)[30]]       # new vertex's page
    assert d[np.asarray(store.edge_page)[2]]        # rewritten neighbor
    assert d.sum() == len({int(np.asarray(store.edge_page)[30]),
                           int(np.asarray(store.edge_page)[2])})


# ---------------------------------------------------------------------------
# capacity guard (in-place insert past n_max)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tight(dataset):
    """An engine with almost no insert headroom (n_max = count + 4)."""
    n_base = 400
    eng = Engine(preset("navis", dim=48, r=16, n_max=n_base + 4,
                        e_search=32, e_pos=40, pq_m=24, max_hops=48,
                        cache_capacity_pages=128, buffer_max=32))
    state = eng.build(jax.random.PRNGKey(3), dataset["vecs"][:n_base],
                      build_block=64, build_e_pos=32)
    return eng, state


def test_insert_inplace_capacity_guard(tight, dataset):
    """Past n_max the whole commit is masked: count saturates, the stats
    carry ``dropped``, and the graph stays well-formed (the unguarded
    path silently lost the scatter writes while count kept climbing)."""
    eng, state = tight
    n_max = state.store.n_max
    newv = _wave(dataset, 7, seed=21)
    flags = []
    for i in range(7):
        stats, state, _ = eng.insert(state, newv[i])
        flags.append(bool(stats.dropped))
    assert flags == [False] * 4 + [True] * 3
    assert int(state.store.count) == n_max
    assert int(state.live_count) == n_max
    _assert_graph_well_formed(state)
    # the accepted inserts really landed and are searchable
    ids, _, _, state = eng.search(state, newv[0])
    assert int(state.store.count) - 4 in np.asarray(ids).tolist()


def test_insert_many_capacity_guard(tight, dataset):
    """A wave overflowing capacity commits the head, drops the tail."""
    eng, state = tight
    n_max = state.store.n_max
    stats, st2 = eng.insert_many(state, _wave(dataset, 7, seed=22))
    assert int(st2.store.count) == n_max
    dropped = np.asarray(stats.dropped)
    assert dropped.tolist() == [False] * 4 + [True] * 3
    # dropped lanes still paid their position seek (phase ① ran against
    # the snapshot) but wrote nothing
    wr = np.asarray(stats.write_requests)
    assert (wr[4:] == 0).all()
    _assert_graph_well_formed(st2)


# ---------------------------------------------------------------------------
# delete correctness (idempotence + entrance edge scrub)
# ---------------------------------------------------------------------------

def test_delete_is_idempotent(navis, dataset):
    eng, state = navis
    vid = jnp.int32(17)
    live0 = int(state.live_count)
    state1 = eng.delete(state, vid)
    state2 = eng.delete(state1, vid)            # double delete: no-op
    assert int(state1.n_deleted) - int(state.n_deleted) == 1
    assert int(state2.n_deleted) == int(state1.n_deleted)
    assert int(state2.live_count) == live0 - 1
    assert bool(state2.tombstone[vid])


def test_delete_scrubs_entrance_edges(navis, dataset):
    """Dropping an entrance member leaves no reciprocal edge pointing at
    the dead slot, so entrance_search can never seed from it."""
    eng, state = navis
    ids = np.asarray(state.ent.ids)
    edges0 = np.asarray(state.ent.edges)
    # a live member some other member links back to
    slot = next(s for s in range(1, len(ids))
                if ids[s] >= 0 and (edges0 == s).sum() > 0)
    vid = int(ids[slot])
    st2 = eng.delete(state, jnp.int32(vid))
    assert int(st2.ent.ids[slot]) == -1
    assert int(st2.ent.main_to_ent[vid]) == -1
    assert (np.asarray(st2.ent.edges) == slot).sum() == 0   # scrubbed
    # deleting again must not disturb the entrance graph further
    st3 = eng.delete(st2, jnp.int32(vid))
    for a, b in zip(jax.tree.leaves(st2.ent), jax.tree.leaves(st3.ent)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_insert_wave_skips_tombstoned_neighbors(navis, dataset):
    """Wave inserts never wire to vertices deleted before the wave."""
    eng, state = navis
    victims = [3, 44, 101]
    for v in victims:
        state = eng.delete(state, jnp.int32(v))
    _, st2 = eng.insert_many(state, _wave(dataset, 6, seed=23))
    n0, n1 = int(state.store.count), int(st2.store.count)
    new_edges = np.asarray(st2.store.edges[n0:n1])
    assert not np.isin(new_edges[new_edges >= 0], victims).any()
    _assert_graph_well_formed(st2)


# ---------------------------------------------------------------------------
# ≥512-insert wave: recall parity with the sequential path (acceptance)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def midsize():
    """A dedicated corpus with enough headroom for a 512-insert wave."""
    key = jax.random.PRNGKey(5)
    from repro.data import make_clustered
    vecs, _, cents = make_clustered(key, 900, 32, n_clusters=10, scale=3.0,
                                    noise=1.0)
    eng = Engine(preset("navis", dim=32, r=16, n_max=1600, e_search=40,
                        e_pos=48, pq_m=16, max_hops=48,
                        cache_capacity_pages=256, buffer_max=64))
    state = eng.build(jax.random.PRNGKey(6), vecs, build_block=64,
                      build_e_pos=32)
    return eng, state, cents


def test_insert_many_wave512_recall_parity(midsize):
    eng, state, cents = midsize
    wave = insert_stream(jax.random.PRNGKey(7), cents, 512, drift=0.2)
    stats_m, st_m = eng.insert_many(state, wave)
    stats_s, st_s = eng.insert_batch(state, wave)
    assert int(st_m.store.count) == int(st_s.store.count)
    assert not np.asarray(stats_m.dropped).any()
    _assert_graph_well_formed(st_m)

    # per-wave counters sum consistently (no double-charged pages)
    delta = jax.tree.map(lambda a, b: a - b, st_m.ctr_insert,
                         state.ctr_insert)
    assert int(np.asarray(stats_m.read_requests).sum()) == \
        int(delta.read_requests)
    assert int(np.asarray(stats_m.write_requests).sum()) == \
        int(delta.write_requests)

    qs = query_stream(jax.random.PRNGKey(8), cents, 100)
    truth = brute_force_topk(qs, st_s.store.vectors,
                             int(st_s.store.count), 10)
    r_m = _recall(eng, st_m, qs, truth)
    r_s = _recall(eng, st_s, qs, truth)
    assert r_m >= r_s - 0.01, (r_m, r_s)      # within one recall point
