"""Product-quantisation properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container: seeded shim
    from _prop import given, settings, st

from repro.core import pq

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def codec():
    x = jax.random.normal(KEY, (800, 32))
    return pq.train_pq(KEY, x, m=16), x


def test_adc_lut_matches_decoded(codec):
    cd, x = codec
    codes = pq.encode(cd, x[:50])
    q = x[60]
    lut = pq.adc_lut(cd, q)
    d_adc = pq.adc_distance(lut, codes)
    d_dec = pq.exact_l2(q, pq.decode_codes(cd, codes))
    np.testing.assert_allclose(d_adc, d_dec, rtol=1e-4, atol=1e-3)


def test_adc_correlates_with_exact(codec):
    cd, x = codec
    codes = pq.encode(cd, x)
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (32,))
    lut = pq.adc_lut(cd, q)
    d_adc = np.asarray(pq.adc_distance(lut, codes))
    d_ex = np.asarray(pq.exact_l2(q, x))
    rho = np.corrcoef(d_adc, d_ex)[0, 1]
    assert rho > 0.8, rho


def test_quantisation_error_decreases_with_m():
    x = jax.random.normal(KEY, (600, 32))
    errs = []
    for m in (4, 8, 16):
        cd = pq.train_pq(KEY, x, m=m)
        rec = pq.decode_codes(cd, pq.encode(cd, x))
        errs.append(float(jnp.mean(jnp.sum((x - rec) ** 2, -1))))
    assert errs[0] > errs[1] > errs[2], errs


def test_sym_distance_properties(codec):
    cd, x = codec
    codes = pq.encode(cd, x[:30])
    t = pq.sym_tables(cd)
    # self-distance ~zero (fp accumulation)
    d_self = pq.sym_distance(t, codes[0], codes[:1])
    assert float(d_self[0]) < 1e-5
    # symmetry
    dab = float(pq.sym_distance(t, codes[0], codes[1:2])[0])
    dba = float(pq.sym_distance(t, codes[1], codes[0:1])[0])
    assert abs(dab - dba) < 1e-3
    # non-negativity
    m = pq.sym_distance_matrix(t, codes)
    assert float(m.min()) >= 0.0


def test_sym_matches_decoded_l2(codec):
    cd, x = codec
    codes = pq.encode(cd, x[:20])
    dec = pq.decode_codes(cd, codes)
    t = pq.sym_tables(cd)
    want = jnp.sum((dec[0] - dec) ** 2, axis=1)
    got = pq.sym_distance(t, codes[0], codes)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([2, 4, 8]), d_per=st.sampled_from([2, 4]),
       seed=st.integers(0, 2 ** 8))
def test_encode_codes_in_range(m, d_per, seed):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (100, m * d_per))
    cd = pq.train_pq(k, x, m=m, iters=2)
    codes = pq.encode(cd, x)
    assert codes.shape == (100, m)
    assert codes.dtype == jnp.uint8


def test_encode_is_nearest_centroid(codec):
    cd, x = codec
    codes = pq.encode(cd, x[:10])
    sub = x[:10].reshape(10, cd.m, cd.dsub)
    for i in range(10):
        for mm in range(0, cd.m, 5):
            d = jnp.sum((cd.codebooks[mm] - sub[i, mm]) ** 2, -1)
            assert int(codes[i, mm]) == int(jnp.argmin(d))
