"""Per-architecture smoke: reduced config, one forward/train/decode step on
CPU, asserting output shapes and no NaNs.  The FULL configs are exercised
only by the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models import transformer as T
from repro.train.optimizer import make_optimizer
from repro.train.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import init_opt_state, make_train_step

ARCHS = list(C.ARCH_IDS)
B, S = 2, 32


def _inputs(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    cross = None
    if cfg.cross_seq:
        cross = jax.random.normal(
            key, (B, cfg.cross_seq, cfg.d_model)).astype(cfg.dtype)
    return tokens, cross


@pytest.mark.parametrize("arch_id", ARCHS)
def test_forward_shapes_no_nan(arch_id):
    arch = C.get_arch(arch_id)
    cfg = arch.smoke
    cfg.validate()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    tokens, cross = _inputs(cfg, key)
    hidden = T.forward(cfg, params, tokens, cross_src=cross, remat=False)
    assert hidden.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(hidden.astype(jnp.float32)).any())
    logits = T.logits_from_hidden(cfg, params, hidden)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch_id", ARCHS)
def test_train_step_decreases_loss(arch_id):
    arch = C.get_arch(arch_id)
    cfg = arch.smoke
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    opt = make_optimizer(arch.optimizer, lr=1e-3)
    opt_state = init_opt_state(cfg, opt, params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    tokens, cross = _inputs(cfg, key)
    batch = {"tokens": tokens}
    if cross is not None:
        batch["cross_src"] = cross
    losses = []
    for i in range(4):
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jnp.int32(i))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses   # same batch -> must descend


@pytest.mark.parametrize("arch_id",
                         [a for a in ARCHS if a != "whisper-medium"])
def test_prefill_then_decode_consistent(arch_id):
    """decode_step after prefill_step continues without shape/NaN issues."""
    arch = C.get_arch(arch_id)
    cfg = arch.smoke
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    tokens, cross = _inputs(cfg, key)
    max_seq = S + 4
    prefill = make_prefill_step(cfg, max_seq=max_seq)
    logits, cache = prefill(params, tokens, cross) if cross is not None \
        else prefill(params, tokens)
    assert logits.shape == (B, cfg.vocab_size)
    decode = make_decode_step(cfg)
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(3):
        cur, logits, cache = decode(params, cache, cur,
                                    jnp.asarray(S + i, jnp.int32))
        cur = cur[:, None]
        assert not bool(jnp.isnan(logits).any())


def test_whisper_decode_against_encoder_stub():
    arch = C.get_arch("whisper-medium")
    cfg = arch.smoke
    key = jax.random.PRNGKey(3)
    params = T.init_params(cfg, key)
    tokens, cross = _inputs(cfg, key)
    prefill = make_prefill_step(cfg, max_seq=S + 4)
    logits, cache = prefill(params, tokens, cross)
    decode = make_decode_step(cfg)
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    cur, logits, cache = decode(params, cache, cur,
                                jnp.asarray(S, jnp.int32))
    assert not bool(jnp.isnan(logits).any())


def test_all_full_configs_validate_and_count():
    """Full configs match their published layer/param structure."""
    for arch_id in ARCHS:
        cfg = C.get_arch(arch_id).model
        cfg.validate()
        n = T.param_count(cfg)
        assert n > 0
    # spot totals (±15%: embeddings/bias conventions differ by report)
    qwen2 = T.param_count(C.get_arch("qwen2-0.5b").model)
    assert 0.35e9 < qwen2 < 0.75e9, qwen2
    gemma = T.param_count(C.get_arch("gemma-2b").model)
    assert 1.8e9 < gemma < 3.3e9, gemma
    moe = C.get_arch("moonshot-v1-16b-a3b").model
    total, active = T.param_count(moe), T.active_param_count(moe)
    # the assigned config (64e x d_ff 1408 x 48L + 163840-row embeddings)
    # arithmetically gives ~28B total / ~4B active; the public "16B" brand
    # counts a shared-expert layout the assignment does not specify
    assert 24e9 < total < 32e9, total
    assert 2e9 < active < 5e9, active
    arctic = C.get_arch("arctic-480b").model
    assert T.param_count(arctic) > 4e11


def test_cells_cover_assignment():
    """40 assigned cells = 10 archs x 4 shapes; skips documented."""
    all_cells = list(C.cells(include_skipped=True))
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2] is None]
    skipped = [c for c in all_cells if c[2] is not None]
    assert len(skipped) == 7          # 7 pure-attention long_500k skips
    assert all(s == "long_500k" for _, s, _ in skipped)
