"""RAG serving: an assigned-architecture LM embeds queries; NAVIS
retrieves.  The LM side runs the same serve_step the multi-pod dry-run
lowers at scale; the retrieval side is the NAVIS engine.

    PYTHONPATH=src python examples/rag_serving.py --arch qwen2-0.5b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core import Engine, preset
from repro.data import make_clustered
from repro.models import transformer as T


def embed_queries(cfg, params, token_batches):
    """Mean-pooled last-hidden-state embeddings from the smoke LM."""
    outs = []
    for tokens in token_batches:
        h = T.forward(cfg, params, tokens, remat=False)
        outs.append(h.mean(axis=1))                    # [B, D]
    return jnp.concatenate(outs).astype(jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list(C.ARCH_IDS))
    args = ap.parse_args()

    arch = C.get_arch(args.arch)
    cfg = arch.smoke
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    dim = cfg.d_model

    # corpus: "documents" embedded by the same LM (random token docs)
    print(f"embedding 512 documents with {args.arch} (smoke config, "
          f"d={dim})...")
    doc_tokens = [jax.random.randint(jax.random.fold_in(key, i),
                                     (64, 32), 0, cfg.vocab_size, jnp.int32)
                  for i in range(8)]
    docs = embed_queries(cfg, params, doc_tokens)

    spec = preset("navis", dim=dim, r=16, n_max=docs.shape[0] + 64,
                  e_search=32, e_pos=40, pq_m=min(32, dim // 2),
                  cache_capacity_pages=64, max_hops=48)
    eng = Engine(spec)
    state = eng.build(jax.random.fold_in(key, 99), docs)
    print(f"indexed {int(state.store.count)} docs")

    # serve: embed a query batch, retrieve top-5 docs each
    q_tokens = jax.random.randint(jax.random.fold_in(key, 1234), (4, 32),
                                  0, cfg.vocab_size, jnp.int32)
    t0 = time.time()
    q_emb = embed_queries(cfg, params, [q_tokens])
    ids, dists, stats, state = eng.search_batch(state, q_emb)
    print(f"retrieved in {time.time()-t0:.2f}s")
    for i in range(4):
        print(f"  query {i}: docs {ids[i][:5].tolist()} "
              f"(d={[round(float(x),1) for x in dists[i][:5]]})")


if __name__ == "__main__":
    main()
