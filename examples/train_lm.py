"""End-to-end training driver: a ~100M-parameter qwen2-family model for a
few hundred steps with checkpointing — the (b) deliverable's train driver.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This wraps launch/train.py with a purpose-built ~100M config (scaled-up
smoke: 8 layers, d_model 512, vocab 32k) instead of the 0.5B full config,
so a few hundred steps finish on one CPU.
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.data import TokenStream
from repro.models.transformer import ModelConfig, uniform_pattern
from repro.models import transformer as T
from repro.train.optimizer import cosine_schedule, make_optimizer
from repro.train.train_step import init_opt_state, make_train_step

CFG_100M = ModelConfig(
    name="qwen2-100m", family="dense",
    num_layers=8, d_model=512, num_heads=8, num_kv_heads=2, d_ff=1536,
    vocab_size=32_000, patterns=uniform_pattern("attn", 8),
    qkv_bias=True, tie_embeddings=True, activation="silu", glu=True,
    param_dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"params: {T.param_count(cfg)/1e6:.1f}M")
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         batch=args.batch, seed=0)
    opt = make_optimizer("adamw", lr=cosine_schedule(
        3e-4, warmup=30, total=args.steps), state_dtype="float32")
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(cfg, opt, params)

    start = 0
    st0, restored = ckpt.load_latest(args.ckpt,
                                     {"params": params, "opt": opt_state})
    if st0 is not None:
        params, opt_state = restored["params"], restored["opt"]
        start = st0 + 1
        print(f"resumed from step {st0}")

    t_start, tok = time.time(), args.batch * args.seq
    for step in range(start, args.steps):
        batch = stream.make_batch(step)
        t0 = time.time()
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jnp.int32(step))
        if step % 25 == 0 or step == args.steps - 1:
            print(json.dumps({"step": step,
                              "loss": round(float(m["loss"]), 4),
                              "tok_per_s": round(tok / (time.time() - t0))}),
                  flush=True)
        if (step + 1) % 100 == 0:
            ckpt.save(args.ckpt, step, {"params": params, "opt": opt_state})
    print(f"done in {time.time()-t_start:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
