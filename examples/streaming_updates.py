"""Streaming updates: concurrent search+insert with a drifting corpus,
comparing NAVIS against OdinANN and FreshDiskANN — the paper's headline
scenario (Fig 10) at laptop scale.

    PYTHONPATH=src python examples/streaming_updates.py
"""
import time

import jax

from benchmarks import common as Cm   # enables x64 for exact counters


def main():
    print("system          insert/s   search QPS   mean lat   recall")
    for system in ("freshdiskann", "odinann", "navis"):
        eng, state, ds = Cm.build_engine(system, "fineweb-like")
        res = Cm.concurrent_run(eng, state, ds, rounds=6, drift=0.3)
        print(f"{system:14s} {res['insert_tput']:9.0f} "
              f"{res['search_qps']:11.0f} "
              f"{res['search_lat_mean_ms']:8.2f}ms "
              f"{res['recall']:8.3f}"
              + (f"   ({res['merges']} merge windows)"
                 if res["merges"] else ""))
    # the same mixed workload served by the batch-parallel fan-outs:
    # insert_many waves (snapshot seek -> serialized commit) + search_many
    eng, state, ds = Cm.build_engine("navis", "fineweb-like")
    res = Cm.concurrent_run(eng, state, ds, rounds=6, drift=0.3,
                            parallel_search=True, parallel_insert=True)
    print(f"{'navis (fan-out)':14s} {res['insert_tput']:9.0f} "
          f"{res['search_qps']:11.0f} "
          f"{res['search_lat_mean_ms']:8.2f}ms "
          f"{res['recall']:8.3f}")
    print("\nwall-times from the SSD cost model (Crucial T705) over exact "
          "per-op I/O counters;\nsee benchmarks/concurrent.py for the full "
          "6-system × 2-dataset sweep\nand the insert fan-out scaling "
          "(experiments/concurrent/fig11.json).")


if __name__ == "__main__":
    main()
