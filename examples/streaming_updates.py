"""Streaming updates: concurrent search+insert with a drifting corpus,
comparing NAVIS against OdinANN and FreshDiskANN — the paper's headline
scenario (Fig 10) at laptop scale — followed by a sustained delete+insert
churn loop that leans on the maintenance subsystem (`Engine.consolidate`
fires whenever `needs_consolidation` trips) to keep accepting writes
forever instead of filling up.

    PYTHONPATH=src python examples/streaming_updates.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as Cm   # enables x64 for exact counters
from repro.data import insert_stream


def main():
    print("system          insert/s   search QPS   mean lat   recall")
    for system in ("freshdiskann", "odinann", "navis"):
        eng, state, ds = Cm.build_engine(system, "fineweb-like")
        res = Cm.concurrent_run(eng, state, ds, rounds=6, drift=0.3)
        print(f"{system:14s} {res['insert_tput']:9.0f} "
              f"{res['search_qps']:11.0f} "
              f"{res['search_lat_mean_ms']:8.2f}ms "
              f"{res['recall']:8.3f}"
              + (f"   ({res['merges']} merge windows)"
                 if res["merges"] else ""))
    # the same mixed workload served by the batch-parallel fan-outs:
    # insert_many waves (snapshot seek -> serialized commit) + search_many
    eng, state, ds = Cm.build_engine("navis", "fineweb-like")
    res = Cm.concurrent_run(eng, state, ds, rounds=6, drift=0.3,
                            parallel_search=True, parallel_insert=True)
    print(f"{'navis (fan-out)':14s} {res['insert_tput']:9.0f} "
          f"{res['search_qps']:11.0f} "
          f"{res['search_lat_mean_ms']:8.2f}ms "
          f"{res['recall']:8.3f}")
    print("\nwall-times from the SSD cost model (Crucial T705) over exact "
          "per-op I/O counters;\nsee benchmarks/concurrent.py for the full "
          "6-system × 2-dataset sweep\nand the insert fan-out scaling "
          "(experiments/concurrent/fig11.json).")
    churn_loop()


def churn_loop(cycles: int = 10, batch: int = 20):
    """Delete+insert churn at full capacity: tombstone a wave, consolidate
    when the trigger fires (reclaiming slots into the free list), insert a
    wave into the reclaimed slots — acceptance stays 100% where the
    pre-maintenance engine silently dropped every insert past n_max."""
    print(f"\nchurn loop ({cycles} cycles × {batch} delete+insert, "
          "maintenance on):")
    eng, state, ds = Cm.build_engine("navis", "smoke",
                                     consolidate_frac=0.15, ent_frac=0.05)
    # fill the fresh headroom so churn exercises reclamation, not append
    spare = int(state.store.n_max - state.store.count)
    if spare:
        _, state = eng.insert_many(state, insert_stream(
            jax.random.PRNGKey(0), ds["cents"], spare, noise=ds["noise"]))
    rng = np.random.default_rng(0)
    dropped = consolidations = 0
    for c in range(cycles):
        live = np.flatnonzero(np.asarray(state.live_mask))
        victims = rng.choice(live, batch, replace=False).astype(np.int32)
        state = eng.delete_many(state, jnp.asarray(victims))
        if bool(eng.needs_consolidation(state, lookahead=batch)):
            mstats, state = eng.consolidate(state)
            consolidations += 1
            print(f"  cycle {c}: consolidate — reclaimed "
                  f"{int(state.free_count)} slots, "
                  f"{int(mstats.read_requests)} reads / "
                  f"{int(mstats.write_requests)} writes charged")
        wave = insert_stream(jax.random.fold_in(jax.random.PRNGKey(1), c),
                             ds["cents"], batch, noise=ds["noise"])
        stats, state = eng.insert_many(state, wave)
        dropped += int(np.asarray(stats.dropped).sum())
    print(f"  {cycles * batch} churn inserts at count=n_max="
          f"{int(state.store.n_max)}: {dropped} dropped, "
          f"{consolidations} consolidations, live={int(state.live_count)}")


if __name__ == "__main__":
    main()
