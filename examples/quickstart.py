"""Quickstart: build a NAVIS index, search it, insert into it — 2 minutes
on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import Engine, preset, brute_force_topk, recall_at_k
from repro.data import insert_stream, make_clustered, query_stream


def main():
    key = jax.random.PRNGKey(0)
    # a clustered corpus standing in for text embeddings
    vecs, _, cents = make_clustered(key, n=2000, dim=64, n_clusters=16)
    queries = query_stream(jax.random.fold_in(key, 1), cents, 50)

    # NAVIS = decoupled layout + CASR + dynamic entrance + NAVIS-cache
    spec = preset("navis", dim=64, r=16, n_max=2500, e_search=40, e_pos=48,
                  pq_m=32, cache_capacity_pages=128, max_hops=64)
    eng = Engine(spec)

    t0 = time.time()
    state = eng.build(jax.random.fold_in(key, 2), vecs)
    print(f"built {int(state.store.count)} vertices in {time.time()-t0:.0f}s"
          f" (entrance graph: {int(state.ent.count)} entries)")

    # --- search ------------------------------------------------------------
    ids, dists, stats, state = eng.search_batch(state, queries)
    truth = brute_force_topk(queries, vecs, 2000, 10)
    print(f"recall@10 = {float(recall_at_k(ids, truth)):.3f}, "
          f"mean I/O = {float(stats.read_requests.mean()):.1f} requests "
          f"/ {float(stats.read_bytes.mean())/1024:.0f} KiB per query")

    # --- concurrent-style insert -------------------------------------------
    new = insert_stream(jax.random.fold_in(key, 3), cents, 20)
    istats, state = eng.insert_batch(state, new)
    print(f"inserted 20 vectors: mean {float(istats.read_requests.mean()):.0f}"
          f" reads, {float(istats.write_requests.mean()):.0f} writes each; "
          f"corpus now {int(state.store.count)}")

    # the freshly inserted vectors are immediately searchable
    ids2, _, _, state = eng.search(state, new[0])
    print("nearest to first inserted vector:", ids2[:3].tolist(),
          "(expect", int(state.store.count) - 20, "first)")


if __name__ == "__main__":
    main()
