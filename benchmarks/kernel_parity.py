"""Kernel dispatch parity smoke: ref oracles vs Pallas interpret mode.

Off-TPU the engine's hot loops run the ``ref.py`` jnp oracles; the Pallas
programs (what a real TPU executes as Mosaic) are validated against those
oracles here via the interpreter, over a small shape sweep per kernel.
Also asserts the dispatch contract: off-TPU the default mode is ``ref``
and ``NAVIS_KERNEL_INTERPRET=1`` flips it to ``interpret`` — no off-TPU
code path may run the (orders-of-magnitude slower) interpreter unless the
flag is set.

Writes ``experiments/kernels/parity.json``; exits non-zero on any
mismatch.  Wired into ``scripts/ci.sh``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as Cm
from repro.kernels import ops, ref
from repro.kernels.pq_adc import adc_distance_pallas
from repro.kernels.rerank_l2 import rerank_l2_pallas
from repro.kernels.topk_pool import pool_merge_pallas

KEY = jax.random.PRNGKey(3)


def _check_dispatch() -> dict:
    """The mode contract (trace-time env read)."""
    on_tpu = jax.default_backend() == "tpu"
    saved = os.environ.pop("NAVIS_KERNEL_INTERPRET", None)
    try:
        default_mode = ops.kernel_mode()
        os.environ["NAVIS_KERNEL_INTERPRET"] = "1"
        flagged_mode = ops.kernel_mode()
    finally:
        os.environ.pop("NAVIS_KERNEL_INTERPRET", None)
        if saved is not None:
            os.environ["NAVIS_KERNEL_INTERPRET"] = saved
    # explicit raises, not asserts: this is a CI gate and must survive -O
    if on_tpu:
        if not (default_mode == flagged_mode == "mosaic"):
            raise SystemExit(f"TPU dispatch broken: {default_mode}/"
                             f"{flagged_mode}")
    elif default_mode != "ref":
        raise SystemExit(f"off-TPU default mode must be 'ref', got "
                         f"{default_mode!r} — the engine would run the "
                         f"Pallas interpreter on every hop")
    elif flagged_mode != "interpret":
        raise SystemExit(f"NAVIS_KERNEL_INTERPRET=1 must select "
                         f"'interpret', got {flagged_mode!r}")
    return {"backend": jax.default_backend(), "default_mode": default_mode,
            "flagged_mode": flagged_mode}


def run() -> list[str]:
    rows = []
    blob = {"dispatch": _check_dispatch(), "kernels": {}}

    cases = []
    for m, b in ((8, 33), (32, 256), (96, 500)):
        lut = jax.random.uniform(jax.random.fold_in(KEY, m), (m, 256))
        codes = jax.random.randint(jax.random.fold_in(KEY, b), (b, m),
                                   0, 256).astype(jnp.uint8)
        got = adc_distance_pallas(lut, codes, interpret=True)
        cases.append(("adc_distance", f"m{m}_b{b}", got,
                      ref.adc_distance_ref(lut, codes), 1e-4))
    for p, d, g in ((17, 48, 4), (100, 768, 8)):
        q = jax.random.normal(jax.random.fold_in(KEY, d), (d,))
        xs = jax.random.normal(jax.random.fold_in(KEY, p), (p, d))
        got = rerank_l2_pallas(q, xs, group=g, interpret=True)
        cases.append(("rerank_l2", f"p{p}_d{d}", got,
                      ref.rerank_l2_ref(q, xs), 1e-3))
    for p, n in ((16, 40), (64, 384)):
        pd = jax.random.uniform(jax.random.fold_in(KEY, p), (p,))
        nd = jax.random.uniform(jax.random.fold_in(KEY, n), (n,))
        pi = jnp.arange(p, dtype=jnp.int32)
        ni = 1000 + jnp.arange(n, dtype=jnp.int32)
        gd, gi = pool_merge_pallas(pd, pi, nd, ni, interpret=True)
        wd, wi = ref.pool_merge_ref(pd, pi, nd, ni)
        cases.append(("pool_merge_d", f"p{p}_n{n}", gd, wd, 1e-6))
        cases.append(("pool_merge_ids", f"p{p}_n{n}",
                      gi.astype(jnp.float32), wi.astype(jnp.float32), 0.0))

    ok = True
    for kernel, label, got, want, tol in cases:
        err = float(jnp.max(jnp.abs(jnp.asarray(got, jnp.float32) -
                                    jnp.asarray(want, jnp.float32))))
        passed = err <= tol if tol else err == 0.0
        ok &= passed
        blob["kernels"][f"{kernel}_{label}"] = {
            "max_abs_err": err, "tol": tol, "pass": bool(passed)}
        rows.append(Cm.fmt_row(f"parity_{kernel}_{label}",
                               max_abs_err=err, ok=int(passed)))

    path = Cm.write_json("kernels/parity.json", blob)
    rows.append(f"# wrote {path}")
    if not ok:
        raise SystemExit("kernel interpret-vs-ref parity FAILED")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
