"""Benchmark aggregator: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (see DESIGN.md §9); each prints CSV
rows ``name,key=value,...``.  ``--quick`` shrinks workloads ~2-3×;
``--only fig10`` runs a single module.  GVS wall-times come from the SSD
cost model over exact I/O counters (benchmarks/common.py); the roofline
module reads the dry-run artifacts in experiments/dryrun/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("fig3_interference", "benchmarks.interference"),
    ("fig4_wasted_io", "benchmarks.wasted_io"),
    ("fig5_entrance_staleness", "benchmarks.entrance_staleness"),
    ("fig10_concurrent", "benchmarks.concurrent"),
    ("fig13_insert_only", "benchmarks.insert_only"),
    ("fig14_ablation", "benchmarks.ablation"),
    ("fig15_tail_latency", "benchmarks.tail_latency"),
    ("fig16_footprint", "benchmarks.footprint"),
    ("fig17_cache_policy", "benchmarks.cache_policy"),
    ("fig18_group_size", "benchmarks.group_size"),
    ("roofline", "benchmarks.roofline"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    args = ap.parse_args(argv)

    failures = 0
    for name, modpath in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        try:
            import importlib
            mod = importlib.import_module(modpath)
            for row in mod.run(quick=args.quick):
                print(row, flush=True)
            print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
        except Exception:                          # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
