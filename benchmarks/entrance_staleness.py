"""Fig 5: (a) entrance-graph staleness vs average search hops under
drifted insertions — w/o entrance, static, dynamic (NAVIS-update);
(b) cost of a full entrance rebuild relative to a single search."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as Cm
from repro.data import insert_stream, query_stream


def run(ds_name: str = "deep-like", quick: bool = False) -> list[str]:
    rows = []
    n_waves = 3 if quick else 5
    per_wave = 50 if quick else 90
    for mode in ("none", "static", "dynamic"):
        eng, state, ds = Cm.build_engine("navis", ds_name, entrance=mode)
        key = jax.random.PRNGKey(7)
        hops_by_wave = []
        for w in range(n_waves):
            # queries drawn from the *drifted* mixture — the newly inserted
            # regions the paper's Fig 5(a) probes
            kq = jax.random.fold_in(key, 100 + w)
            drift = 0.5 * (w + 1) / n_waves
            qs = insert_stream(kq, ds["cents"], 40, noise=ds["noise"],
                               drift=drift)
            _, _, st_s, state = eng.search_batch(state, qs)
            hops_by_wave.append(float(np.asarray(
                st_s.serial_rounds).mean()))
            newv = insert_stream(jax.random.fold_in(key, w), ds["cents"],
                                 per_wave, noise=ds["noise"], drift=drift)
            _, state = eng.insert_batch(state, newv)
        rows.append(Cm.fmt_row(
            f"fig5a_{mode}", first_wave_hops=hops_by_wave[0],
            last_wave_hops=hops_by_wave[-1],
            hops_growth=hops_by_wave[-1] / max(hops_by_wave[0], 1e-9),
            ent_count=int(state.ent.count)))

    # (b) full rebuild vs one search, via the cost model:
    # rebuild = |G_ent| position-seeks on the main graph (DiskANN-style
    # rebuild); search = one modeled search latency.  Reported at our scale
    # and extrapolated to the paper's (1M entrance vertices).
    eng, state, ds = Cm.build_engine("navis", ds_name)
    qs = query_stream(jax.random.PRNGKey(8), ds["cents"], 20,
                      noise=ds["noise"])
    _, _, st_s, state = eng.search_batch(state, qs)
    search_lat = float(Cm.latencies_s(st_s).mean())
    newv = insert_stream(jax.random.PRNGKey(9), ds["cents"], 20,
                         noise=ds["noise"])
    st_i, state = eng.insert_batch(state, newv)
    seek_lat = float(Cm.latencies_s(st_i).mean())
    ent_n = int(state.ent.count)
    ratio_here = ent_n * seek_lat / search_lat
    ratio_paper = 1_000_000 * seek_lat / search_lat
    rows.append(Cm.fmt_row("fig5b_rebuild_cost",
                           rebuild_vs_search_ratio=ratio_here,
                           extrapolated_paper_scale=ratio_paper,
                           navis_update_cost_vs_search=0.0))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
