"""Fig 16: peak host-memory and storage usage per system — plus the
per-query traversal-state scaling curve.

Host memory = PQ codes + entrance graph + indirection table + cache
capacity + (FreshDiskANN) insertion buffer.  Storage = live pages × 4 KiB
(+ FreshDiskANN's double buffer during merge).

``--state-scaling`` (also :func:`state_scaling`) reports the bytes of
per-query traversal state each ``disk_traverse`` lane carries, hashed
visited sets vs the dense bitmap reference, across corpus sizes — the
hashed curve must be FLAT (state bounded by ``max_hops × beam_width``,
not ``n_max``), which is what lets ``search_many`` / ``insert_many``
waves scale past the corpus size.  Pure shape math (no engine builds);
writes ``experiments/footprint/state_scaling.json`` and exits non-zero
if the hashed curve is not flat."""
from __future__ import annotations

import numpy as np

from benchmarks import common as Cm
from repro.core import search as search_mod
from repro.core.iomodel import PAGE_BYTES


def run(ds_name: str = "fineweb-like", quick: bool = False) -> list[str]:
    rows = []
    for system in ("freshdiskann", "odinann", "odinann_cache", "navis"):
        eng, state, ds = Cm.build_engine(system, ds_name)
        spec = eng.spec
        n = int(state.store.count)
        pq_b = n * spec.pq_m
        ind_b = n * 8                              # (page, slot) per vertex
        ent_b = int(state.ent.ids.nbytes + state.ent.edges.nbytes) \
            if spec.entrance != "none" else 0
        cache_b = spec.cache_capacity_pages * PAGE_BYTES \
            if spec.cache_policy != "none" else 0
        buf_b = spec.buffer_max * ds["dim"] * 4 \
            if spec.update_path == "buffered" else 0
        host = pq_b + ind_b + ent_b + cache_b + buf_b

        lspec = spec.lspec
        if spec.layout == "packed":
            pages = n * lspec.packed_pages_per_vertex
        else:
            pages = int(np.ceil(n / lspec.edgelists_per_page)) + \
                int(np.ceil(n * lspec.vector_bytes / PAGE_BYTES))
        storage = pages * PAGE_BYTES
        if spec.update_path == "buffered":
            storage *= 2                           # double-buffered merge
        rows.append(Cm.fmt_row(f"fig16_{system}",
                               host_MiB=host / 2 ** 20,
                               storage_MiB=storage / 2 ** 20))
    return rows


def state_scaling(sizes=(10_000, 100_000, 1_000_000, 10_000_000), *,
                  pool_size: int = 100, beam_width: int = 4,
                  max_hops: int = 256, batch: int = 512) -> list[str]:
    """Per-query traversal state bytes vs corpus size (hash vs bitmap)."""
    rows = []
    blob = {"params": dict(pool_size=pool_size, beam_width=beam_width,
                           max_hops=max_hops, batch=batch),
            "sizes": list(sizes), "hash_bytes": [], "bitmap_bytes": [],
            "hash_wave_mib": [], "bitmap_wave_mib": []}
    for n_max in sizes:
        kw = dict(n_max=n_max, p_max=2 * n_max, pool_size=pool_size,
                  beam_width=beam_width, max_hops=max_hops, frozen=True)
        h = search_mod.traversal_state_bytes(visited="hash", **kw)
        b = search_mod.traversal_state_bytes(visited="bitmap", **kw)
        blob["hash_bytes"].append(h)
        blob["bitmap_bytes"].append(b)
        blob["hash_wave_mib"].append(batch * h / 2 ** 20)
        blob["bitmap_wave_mib"].append(batch * b / 2 ** 20)
        rows.append(Cm.fmt_row(f"state_n{n_max}", hash_B=h, bitmap_B=b,
                               hash_wave_MiB=batch * h / 2 ** 20,
                               bitmap_wave_MiB=batch * b / 2 ** 20))
    flat = len(set(blob["hash_bytes"])) == 1
    blob["hash_flat_in_n_max"] = flat
    path = Cm.write_json("footprint/state_scaling.json", blob)
    rows.append(f"# wrote {path}")
    if not flat:
        raise SystemExit(
            f"hashed traversal state is NOT flat in n_max: "
            f"{blob['hash_bytes']}")
    return rows


if __name__ == "__main__":
    import sys
    out = state_scaling() if "--state-scaling" in sys.argv else run()
    for r in out:
        print(r)
