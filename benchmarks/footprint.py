"""Fig 16: peak host-memory and storage usage per system.

Host memory = PQ codes + entrance graph + indirection table + cache
capacity + (FreshDiskANN) insertion buffer.  Storage = live pages × 4 KiB
(+ FreshDiskANN's double buffer during merge)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as Cm
from repro.core.iomodel import PAGE_BYTES


def run(ds_name: str = "fineweb-like", quick: bool = False) -> list[str]:
    rows = []
    for system in ("freshdiskann", "odinann", "odinann_cache", "navis"):
        eng, state, ds = Cm.build_engine(system, ds_name)
        spec = eng.spec
        n = int(state.store.count)
        pq_b = n * spec.pq_m
        ind_b = n * 8                              # (page, slot) per vertex
        ent_b = int(state.ent.ids.nbytes + state.ent.edges.nbytes) \
            if spec.entrance != "none" else 0
        cache_b = spec.cache_capacity_pages * PAGE_BYTES \
            if spec.cache_policy != "none" else 0
        buf_b = spec.buffer_max * ds["dim"] * 4 \
            if spec.update_path == "buffered" else 0
        host = pq_b + ind_b + ent_b + cache_b + buf_b

        lspec = spec.lspec
        if spec.layout == "packed":
            pages = n * lspec.packed_pages_per_vertex
        else:
            pages = int(np.ceil(n / lspec.edgelists_per_page)) + \
                int(np.ceil(n * lspec.vector_bytes / PAGE_BYTES))
        storage = pages * PAGE_BYTES
        if spec.update_path == "buffered":
            storage *= 2                           # double-buffered merge
        rows.append(Cm.fmt_row(f"fig16_{system}",
                               host_MiB=host / 2 ** 20,
                               storage_MiB=storage / 2 ** 20))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
