"""Fig 15: search P90/P99 latency under concurrent updates."""
from __future__ import annotations

from benchmarks import common as Cm


def run(ds_name: str = "fineweb-like", quick: bool = False) -> list[str]:
    rows = []
    out = {}
    for system in ("freshdiskann", "odinann", "navis"):
        eng, state, ds = Cm.build_engine(system, ds_name)
        res = Cm.concurrent_run(eng, state, ds, rounds=5 if quick else 8)
        out[system] = res
        rows.append(Cm.fmt_row(f"fig15_{system}",
                               p90_ms=res["search_lat_p90_ms"],
                               p99_ms=res["search_lat_p99_ms"]))
    rows.append(Cm.fmt_row(
        "fig15_navis_reduction",
        p90_vs_fresh=1 - out["navis"]["search_lat_p90_ms"]
        / out["freshdiskann"]["search_lat_p90_ms"],
        p99_vs_fresh=1 - out["navis"]["search_lat_p99_ms"]
        / out["freshdiskann"]["search_lat_p99_ms"],
        p90_vs_odin=1 - out["navis"]["search_lat_p90_ms"]
        / out["odinann"]["search_lat_p90_ms"],
        p99_vs_odin=1 - out["navis"]["search_lat_p99_ms"]
        / out["odinann"]["search_lat_p99_ms"]))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
