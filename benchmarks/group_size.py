"""Fig 18(a): CASR group-size sensitivity — insert throughput at s = 1,
calibrated P25, and |E_pos| (full fetch)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as Cm
from repro.data import insert_stream, query_stream


def run(ds_name: str = "fineweb-like", quick: bool = False) -> list[str]:
    rows = []
    ds0 = Cm.DATASETS[ds_name]
    n_ins = 40 if quick else 80

    # calibrate P25 once
    eng, state, ds = Cm.build_engine("navis", ds_name)
    qs = query_stream(jax.random.PRNGKey(21), ds["cents"], 32,
                      noise=ds["noise"])
    spec_cal = eng.calibrate(state, qs)
    p25 = spec_cal.s_pos
    rows.append(Cm.fmt_row("fig18a_calibrated", s_search=spec_cal.s_search,
                           s_pos=p25))

    for s in sorted({1, p25, ds0["e_pos"]}):
        eng, state, ds = Cm.build_engine("navis", ds_name, s_pos=s)
        newv = insert_stream(jax.random.PRNGKey(22), ds["cents"], n_ins,
                             noise=ds["noise"])
        stats, state = eng.insert_batch(state, newv)
        wall = Cm.concurrent_walltime_s([stats], threads=32)
        loads = float(np.asarray(stats.read_requests).mean())
        rows.append(Cm.fmt_row(f"fig18a_s{s}",
                               insert_tput=n_ins / wall,
                               mean_read_requests=loads))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
