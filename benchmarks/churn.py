"""Sustained delete+insert churn with and without the maintenance
subsystem (ISSUE 4): the production steady state the engine previously
could not run at all.

Each cycle tombstones ``batch`` random live vertices and inserts a fresh
``batch``-vector wave through the two-phase ``insert_many`` fan-out; the
maintenance arm calls ``Engine.consolidate`` whenever
``needs_consolidation(state, lookahead=batch)`` fires (tombstone-fraction
threshold or capacity pressure), the control arm never consolidates.  The
full run totals ≥ 3× ``n_max`` inserts per arm.

Measured (SSD-cost-model numbers over exact ``IOCounters``, per the
repo's standard — never host wall-clock):

* insert acceptance — the maintenance arm must accept 100%; the control
  arm demonstrably drops once ``count`` hits ``n_max``;
* recall trajectory against the exact live set (``brute_force_topk``
  with a live mask), gated within one point of the fresh-build baseline;
* per-query read requests and ``tombstone_skips`` (explored-pool slots
  wasted on dead vertices) — flat with maintenance, inflating without;
* consolidation I/O priced by the SSD model next to the foreground
  search/insert I/O;
* live-vertex search parity (ids AND dists) across the first
  consolidation pass of the run.

``python -m benchmarks.churn`` writes ``experiments/churn/churn.json``
and exits non-zero if the maintenance arm drops an insert, degrades
recall beyond tolerance, or breaks search parity.  ``--smoke`` is the
CI-scale version wired into scripts/ci.sh (same gates, shorter run).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as Cm
from repro.core import brute_force_topk, check_invariants, recall_at_k
from repro.data import insert_stream, query_stream

RECALL_TOL = 0.01           # "within 1 point of the fresh-build baseline"


def _pick_victims(rng, state, n, batch):
    """``n`` random live ids, padded with -1 to the jit-stable ``batch``."""
    live = np.flatnonzero(np.asarray(state.live_mask))
    n = min(n, len(live))
    out = np.full((batch,), -1, np.int32)
    out[:n] = rng.choice(live, n, replace=False)
    return jnp.asarray(out)


def _probe(eng, state, qs):
    """Searchable-set recall + per-query read/skip rates for one probe
    wave (the probe's cache effects stay in the state — steady-state
    measurement, like the paper's warmed runs)."""
    c0 = state.ctr_search
    ids, _, _, state = eng.search_many(state, qs)
    truth = brute_force_topk(qs, state.store.vectors, state.live_mask, 10)
    nq = qs.shape[0]
    return state, dict(
        recall=float(recall_at_k(ids, truth)),
        reads_per_q=(int(state.ctr_search.read_requests)
                     - int(c0.read_requests)) / nq,
        skips_per_q=(int(state.ctr_search.tombstone_skips)
                     - int(c0.tombstone_skips)) / nq)


def run_arm(eng, state, ds, *, maintenance: bool, cycles: int, batch: int,
            probe_every: int, seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    qs = query_stream(jax.random.fold_in(key, 9999), ds["cents"], 40,
                      noise=ds["noise"])
    floor = ds["n"] // 2      # the control arm stops deleting here — with
    # inserts dropping, unbounded deletes would just empty the corpus

    total = accepted = consolidations = 0
    i_stats, m_stats = [], []
    records, parity = [], None
    state, p = _probe(eng, state, qs)
    records.append(dict(cycle=-1, live=int(state.live_count),
                        count=int(state.store.count), accepted=0,
                        total=0, **p))
    for c in range(cycles):
        live = int(state.live_count)
        victims = _pick_victims(rng, state, min(batch, max(live - floor, 0)),
                                batch)
        state = eng.delete_many(state, victims)

        if maintenance and bool(eng.needs_consolidation(state,
                                                        lookahead=batch)):
            if parity is None:      # ids/dists preserved across the pass
                ids0, d0, _, state = eng.search_many(state, qs)
            mstat, state = eng.consolidate(state)
            m_stats.append(mstat)
            consolidations += 1
            if parity is None:
                ids1, d1, _, state = eng.search_many(state, qs)
                parity = dict(
                    ids_equal=bool((ids0 == ids1).all()),
                    dists_equal=bool((d0 == d1).all()),
                    id_frac=float((np.asarray(ids0) ==
                                   np.asarray(ids1)).mean()))

        wave = insert_stream(jax.random.fold_in(key, c), ds["cents"],
                             batch, noise=ds["noise"])
        stats, state = eng.insert_many(state, wave)
        i_stats.append(stats)
        dropped = int(np.asarray(stats.dropped).sum())
        total += batch
        accepted += batch - dropped

        if c % probe_every == probe_every - 1 or c == cycles - 1:
            state, p = _probe(eng, state, qs)
            records.append(dict(cycle=c, live=int(state.live_count),
                                count=int(state.store.count),
                                accepted=accepted, total=total, **p))

    inv = check_invariants(state.store, state.tombstone)
    maint_io_s = sum(Cm.device_time_s(s) for s in m_stats)
    insert_io_s = sum(Cm.device_time_s(s) for s in i_stats)
    last3 = [r["recall"] for r in records[-3:]]
    return dict(
        maintenance=maintenance,
        total_inserts=total, accepted=accepted,
        dropped=total - accepted,
        acceptance=accepted / max(total, 1),
        consolidations=consolidations,
        recall_final=records[-1]["recall"],
        recall_last3_mean=float(np.mean(last3)),
        reads_per_q_final=records[-1]["reads_per_q"],
        skips_per_q_final=records[-1]["skips_per_q"],
        live_final=int(state.live_count),
        maintenance_io_s=maint_io_s,
        insert_io_s=insert_io_s,
        io_overhead_frac=maint_io_s / max(insert_io_s + maint_io_s, 1e-12),
        parity=parity,
        invariants_ok=all(bool(v) for v in inv.values()),
        records=records)


def run(smoke: bool = False) -> tuple[list[str], bool]:
    rows: list[str] = []
    # ent_frac is scaled up from the paper's 1% so the entrance covers the
    # toy corpus's cluster regions the way a 1% sample covers a 60M-vector
    # one — at 6 members / 12 clusters, position seeks for inserts into a
    # region whose bridges died get mis-wired and navigability decays
    # (a pure toy-scale artifact; see README "Maintenance & reclamation")
    eng, state0, ds = Cm.build_engine("navis", "churn",
                                      consolidate_frac=0.15,
                                      ent_frac=0.05)
    n_max = int(state0.store.n_max)
    batch = 25
    if smoke:
        cycles, probe_every = 16, 4           # 400 inserts/arm at CI scale
    else:
        cycles = -(-3 * n_max // batch)       # ≥ 3× n_max inserts per arm
        probe_every = 6

    baseline = run_arm(eng, state0, ds, maintenance=True, cycles=0,
                       batch=batch, probe_every=1)["recall_final"]
    arms = {}
    for name, maint in (("maintenance", True), ("no_maintenance", False)):
        res = run_arm(eng, state0, ds, maintenance=maint, cycles=cycles,
                      batch=batch, probe_every=probe_every)
        arms[name] = res
        rows.append(Cm.fmt_row(
            f"churn_{name}",
            total_inserts=res["total_inserts"],
            acceptance=res["acceptance"], dropped=res["dropped"],
            consolidations=res["consolidations"],
            recall=res["recall_last3_mean"],
            reads_per_q=res["reads_per_q_final"],
            skips_per_q=res["skips_per_q_final"],
            maint_io_s=res["maintenance_io_s"]))

    m, nm = arms["maintenance"], arms["no_maintenance"]
    blob = dict(config=dict(dataset="churn", n_max=n_max, batch=batch,
                            cycles=cycles, smoke=smoke,
                            consolidate_frac=0.15),
                baseline_recall=baseline, arms=arms)
    # the CI smoke must not clobber the committed full-run artifact
    path = Cm.write_json(
        "churn/churn_smoke.json" if smoke else "churn/churn.json", blob)
    rows.append(f"# wrote {path}")

    # -- acceptance gates (ISSUE 4) --------------------------------------
    ok = True
    if m["dropped"] != 0:
        rows.append(f"FAIL maintenance arm dropped {m['dropped']} inserts")
        ok = False
    if m["recall_last3_mean"] < baseline - RECALL_TOL:
        rows.append(f"FAIL recall {m['recall_last3_mean']:.3f} degraded "
                    f"beyond {baseline:.3f} - {RECALL_TOL}")
        ok = False
    if not (m["parity"] and m["parity"]["ids_equal"]
            and m["parity"]["dists_equal"]):
        rows.append(f"FAIL search parity across consolidation: "
                    f"{m['parity']}")
        ok = False
    if not m["invariants_ok"]:
        rows.append("FAIL graph invariants after churn")
        ok = False
    if nm["dropped"] == 0:
        rows.append("WARN control arm dropped nothing — churn too small "
                    "to demonstrate degradation")
        ok = ok and smoke    # the full run must demonstrate the contrast
    rows.append(Cm.fmt_row(
        "churn_contrast",
        baseline_recall=baseline,
        maint_recall=m["recall_last3_mean"],
        nomaint_recall=nm["recall_last3_mean"],
        maint_acceptance=m["acceptance"],
        nomaint_acceptance=nm["acceptance"],
        nomaint_skips_per_q=nm["skips_per_q_final"],
        maint_skips_per_q=m["skips_per_q_final"]))
    return rows, ok


if __name__ == "__main__":
    rows, ok = run(smoke="--smoke" in sys.argv)
    for r in rows:
        print(r)
    sys.exit(0 if ok else 1)
