"""§Roofline: three-term roofline per (arch × shape) from the dry-run.

    compute_s    = FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
    memory_s     = HBM bytes / HBM bw            (819 GB/s)
    collective_s = collective bytes / link bw    (50 GB/s/link ICI)

All terms are per-device per-step (the HLO module is the partitioned
program).  FLOPs and bytes come from the trip-count-corrected HLO parse
(launch/hlo_analysis.py) because ``cost_analysis()`` counts while bodies
once; the raw cost_analysis numbers are kept for comparison.  MODEL_FLOPS
= 6·N_active·tokens (train) / 2·N_active·tokens (inference) per device.
"""
from __future__ import annotations

import gzip
import json
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link

DRYRUN_DIR = Path("experiments/dryrun")


def model_flops_per_device(arch_id: str, shape_name: str,
                           devices: int) -> float:
    from repro import configs as C
    from repro.models import transformer as T
    arch = C.get_arch(arch_id)
    shape = C.SHAPES[shape_name]
    n_active = T.active_param_count(arch.model)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        total = 2.0 * n_active * tokens
    return total / devices


def cell_roofline(tag: str) -> dict | None:
    jf = DRYRUN_DIR / f"{tag}.json"
    hf = DRYRUN_DIR / f"{tag}.hlo.txt.gz"
    if not jf.exists():
        return None
    meta = json.loads(jf.read_text())
    if hf.exists():
        from repro.launch import hlo_analysis as H
        from repro import configs as C
        import jax.numpy as jnp
        cfg = C.get_arch(meta["arch"]).model
        hlo = gzip.open(hf, "rt").read()
        a = H.analyze(hlo, bf16_collectives=cfg.dtype == jnp.bfloat16)
        flops = a["dot_flops"]
        bytes_ = a["hbm_traffic_bytes"]
        coll = a["collectives"]["bytes_by_kind"]["total"]
    else:
        flops = meta.get("flops") or 0.0
        bytes_ = meta.get("bytes_accessed") or 0.0
        coll = meta["collectives"]["bytes_by_kind"]["total"]

    devices = meta["devices"]
    mf = model_flops_per_device(meta["arch"], meta["shape"], devices)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    coll_s = coll / LINK_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])
    bound = max(compute_s, memory_s, coll_s)
    return dict(
        arch=meta["arch"], shape=meta["shape"], mesh=meta["mesh"],
        devices=devices,
        flops=flops, hbm_bytes=bytes_, coll_bytes=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dom[0],
        model_flops=mf,
        useful_ratio=mf / flops if flops else 0.0,
        mfu_bound=(mf / PEAK_FLOPS) / bound if bound else 0.0,
        cost_analysis_flops=meta.get("flops"),
    )


def run(quick: bool = False, mesh_name: str = "pod16x16") -> list[str]:
    from repro import configs as C
    rows = []
    for arch_id, shape_name, _ in C.cells():
        tag = f"{arch_id}__{shape_name}__{mesh_name}"
        r = cell_roofline(tag)
        if r is None:
            rows.append(f"roofline_{tag},MISSING")
            continue
        rows.append(
            f"roofline,{r['arch']},{r['shape']},{r['dominant']},"
            f"compute_s={r['compute_s']:.3e},memory_s={r['memory_s']:.3e},"
            f"collective_s={r['collective_s']:.3e},"
            f"useful_ratio={r['useful_ratio']:.3f},"
            f"mfu_bound={r['mfu_bound']:.3f}")
    return rows


def table(mesh_name: str = "pod16x16") -> list[dict]:
    from repro import configs as C
    out = []
    for arch_id, shape_name, _ in C.cells():
        r = cell_roofline(f"{arch_id}__{shape_name}__{mesh_name}")
        if r:
            out.append(r)
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
