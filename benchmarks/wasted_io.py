"""Fig 4(a): per-insertion read/write volume decomposition under the
packed layout — useful vector / wasted vector / edgelist / padding — as
|E_pos| grows past R (the position-seeking regime)."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks import common as Cm
from repro.data import insert_stream


def run(ds_name: str = "fineweb-like", quick: bool = False) -> list[str]:
    rows = []
    ds0 = Cm.DATASETS[ds_name]
    n_ins = 15 if quick else 40
    for e_pos in (ds0["r"], int(ds0["r"] * 1.35), int(ds0["r"] * 1.7)):
        eng, state, ds = Cm.build_engine("odinann", ds_name, e_pos=e_pos)
        ctr0 = state.ctr_insert
        newv = insert_stream(jax.random.PRNGKey(4), ds["cents"], n_ins,
                             noise=ds["noise"])
        stats, state = eng.insert_batch(state, newv)
        c = jax.tree.map(lambda a, b: (np.asarray(b) - np.asarray(a))
                         / n_ins, ctr0, state.ctr_insert)
        read_total = (c.edge_bytes_read + c.useful_vec_bytes_read +
                      c.wasted_vec_bytes_read + c.pad_bytes_read)
        write_total = (c.edge_bytes_written + c.vec_bytes_written +
                       c.wasted_vec_bytes_written + c.pad_bytes_written)
        rows.append(Cm.fmt_row(
            f"fig4a_epos{e_pos}",
            read_KiB=float(read_total / 1024),
            read_useful_vec_frac=float(c.useful_vec_bytes_read / read_total),
            read_wasted_vec_frac=float(c.wasted_vec_bytes_read / read_total),
            read_edge_frac=float(c.edge_bytes_read / read_total),
            read_pad_frac=float(c.pad_bytes_read / read_total),
            write_KiB=float(write_total / 1024),
            write_wasted_vec_frac=float(
                c.wasted_vec_bytes_written / write_total),
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
