"""Shared benchmark infrastructure: datasets, engine zoo, SSD time model.

Scale note (DESIGN.md §7): the paper's datasets are 60–120M vectors on an
NVMe SSD; this container is one CPU core, so each benchmark reproduces the
paper's *ratios* on synthetic clustered corpora (3–4k vectors, the paper's
dimensionalities) under the exact I/O accounting of core/iomodel.py — the
per-op page/request/byte counts are exact, and wall-times come from the
SSD cost model (Crucial T705 parameters, as in the paper's §9.1 rig).

Engines share one graph bundle per dataset (the proximity graph is
layout-independent), so the 6-system sweeps don't pay 6 builds.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax

jax.config.update("jax_enable_x64", True)   # counters are true int64 here

import jax.numpy as jnp
import numpy as np

from repro.core import Engine, EngineSpec, SSDModel, preset
from repro.core import recall_at_k, brute_force_topk
from repro.data import insert_stream, make_clustered, query_stream

SSD = SSDModel()

SYSTEMS = ("freshdiskann", "odinann", "odinann_cache", "layout_only",
           "sel_vec", "navis")

# Paper-analog datasets (dim & PQ bytes follow Table 1; counts are
# CPU-scale, ratios — not absolute throughput — are the reproduction).
DATASETS = {
    # FineWeb/MSMARCO analog: 768-dim text-like, packed page holds ONE record
    "fineweb-like": dict(n=3000, dim=768, pq_m=96, n_clusters=24,
                         noise=1.0, r=48, e_search=40, e_pos=64,
                         extra=1200),
    # DEEP analog: 96-dim image-like (page-level co-residency regime)
    "deep-like": dict(n=4000, dim=96, pq_m=32, n_clusters=24,
                      noise=0.6, r=32, e_search=40, e_pos=80,
                      extra=1200),
    # CI-scale corpus for `benchmarks.concurrent --smoke`
    "smoke": dict(n=600, dim=48, pq_m=24, n_clusters=10, noise=1.0,
                  r=16, e_search=32, e_pos=40, extra=300),
    # benchmarks.churn: sized so "3× n_max total inserts" stays CPU-feasible
    # (n_max = 600 ⇒ 1800 churn inserts per arm); stationary distribution
    # so recall trajectories are comparable to the fresh-build baseline
    "churn": dict(n=500, dim=48, pq_m=24, n_clusters=12, noise=1.0,
                  r=16, e_search=40, e_pos=48, extra=100),
}

_BUNDLES: dict = {}
_STATES: dict = {}


def dataset(name: str):
    d = DATASETS[name]
    # zlib.crc32, not hash(): str hashing is salted per process, which made
    # every benchmark invocation generate a *different* corpus (numbers in
    # experiments/*.json were irreproducible run to run)
    import zlib
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % 2 ** 31)
    vecs, assign, cents = make_clustered(
        key, d["n"], d["dim"], n_clusters=d["n_clusters"], noise=d["noise"])
    queries = query_stream(jax.random.fold_in(key, 1), cents, 200,
                           noise=d["noise"])
    return dict(vecs=vecs, cents=cents, queries=queries, **d)


def spec_for(system: str, ds: dict, **overrides) -> EngineSpec:
    kw = dict(dim=ds["dim"], r=ds["r"], n_max=ds["n"] + ds["extra"],
              pq_m=ds["pq_m"], e_search=ds["e_search"], e_pos=ds["e_pos"],
              cache_capacity_pages=256, max_hops=96, buffer_max=256)
    kw.update(overrides)
    return preset(system, **kw)


def build_engine(system: str, ds_name: str, **overrides):
    """(engine, fresh state) for a system on a dataset, sharing the graph
    bundle across systems."""
    ds = dataset(ds_name) if isinstance(ds_name, str) else ds_name
    key = jax.random.PRNGKey(42)
    eng = Engine(spec_for(system, ds, **overrides))
    tag = ds_name if isinstance(ds_name, str) else id(ds_name)
    if tag not in _BUNDLES:
        t0 = time.time()
        base = Engine(spec_for("navis", ds, **overrides))
        st = base.build(key, ds["vecs"], build_block=64,
                        build_e_pos=min(ds["e_pos"], 64))
        _BUNDLES[tag] = base.bundle(st)
        print(f"# built {tag} graph in {time.time()-t0:.0f}s")
    state = eng.build(key, ds["vecs"], shared=_BUNDLES[tag])
    return eng, state, ds


# ---------------------------------------------------------------------------
# Time modelling (OpStats -> seconds on the paper's rig)
# ---------------------------------------------------------------------------

def op_latency_s(stats, i: int) -> float:
    """Latency of op i: dependent I/O rounds pay the per-request latency;
    its own bytes pay bandwidth."""
    rounds = float(np.asarray(stats.serial_rounds)[i])
    rb = float(np.asarray(stats.read_bytes)[i])
    wb = float(np.asarray(stats.write_bytes)[i])
    return (rounds * SSD.request_latency + rb / SSD.read_bw
            + wb / SSD.write_bw)


def latencies_s(stats) -> np.ndarray:
    rounds = np.asarray(stats.serial_rounds, np.float64)
    rb = np.asarray(stats.read_bytes, np.float64)
    wb = np.asarray(stats.write_bytes, np.float64)
    return (rounds * SSD.request_latency + rb / SSD.read_bw
            + wb / SSD.write_bw)


def device_time_s(stats) -> float:
    """Wall time the SSD needs to serve every op in ``stats`` (batched):
    max of the IOPS bound and the bandwidth bound, read + write."""
    reads = float(np.asarray(stats.read_requests).sum())
    writes = float(np.asarray(stats.write_requests).sum())
    rb = float(np.asarray(stats.read_bytes).sum())
    wb = float(np.asarray(stats.write_bytes).sum())
    return (max(reads / SSD.read_iops, rb / SSD.read_bw)
            + max(writes / SSD.write_iops, wb / SSD.write_bw))


def concurrent_walltime_s(all_stats: list, threads: int) -> float:
    """Concurrent window wall-time: the device bound and the per-thread
    serial bound (ops round-robined over ``threads``)."""
    device = sum(device_time_s(s) for s in all_stats)
    lats = np.concatenate([latencies_s(s) for s in all_stats])
    per_thread = np.zeros(threads)
    for i, l in enumerate(lats):
        per_thread[i % threads] += l
    return max(device, float(per_thread.max()))


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------

def open_workload_model(s_stats: list, i_stats: list, *,
                        search_threads: int = 22,
                        insert_threads: int = 10) -> dict:
    """Open-workload steady state on one shared SSD (paper §9.1: 22 search
    + 10 insert threads issuing back-to-back).

    Each stream's offered rate is threads / mean-latency; latency inflates
    with device utilisation ρ as base/(1−ρ) (processor-sharing
    approximation) — five fixed-point rounds converge it.  This captures
    the interference the paper measures: insert-heavy systems push ρ up
    and search latency/throughput degrade.
    """
    s_lat = np.concatenate([latencies_s(s) for s in s_stats])
    i_lat = np.concatenate([latencies_s(s) for s in i_stats]) \
        if i_stats else np.array([0.0])
    d_s = sum(device_time_s(s) for s in s_stats) / max(len(s_lat), 1)
    d_i = (sum(device_time_s(s) for s in i_stats) / max(len(i_lat), 1)
           if i_stats else 0.0)
    Ls0, Li0 = float(s_lat.mean()), float(i_lat.mean())

    rho = 0.0
    for _ in range(40):                   # damped fixed point (oscillates
        infl = 1.0 / max(1.0 - rho, 0.05)  # undamped near saturation)
        lam_s = search_threads / max(Ls0 * infl, 1e-12)
        lam_i = (insert_threads / max(Li0 * infl, 1e-12)
                 if Li0 > 0 else 0.0)
        rho = 0.7 * rho + 0.3 * min(0.95, lam_s * d_s + lam_i * d_i)
    infl = 1.0 / max(1.0 - rho, 0.05)
    lam_s = search_threads / max(Ls0 * infl, 1e-12)
    lam_i = insert_threads / max(Li0 * infl, 1e-12) if Li0 > 0 else 0.0
    return dict(search_qps=lam_s, insert_tput=lam_i, rho=rho,
                lat_inflation=infl,
                search_lat=s_lat * infl, insert_lat=i_lat * infl)


def concurrent_run(eng, state, ds, *, rounds: int = 12,
                   searches_per_round: int = 22, inserts_per_round: int = 10,
                   drift: float = 0.3, seed: int = 0,
                   parallel_search: bool = False,
                   parallel_insert: bool = False):
    """Interleaved search+insert workload (paper §9.1: 22 search / 10
    insert threads).  Returns dict of throughput/latency/recall metrics.
    Recall of each round's queries is judged against the corpus as of that
    round (inserted vectors count once they are searchable).

    ``parallel_search=True`` serves each round's query wave through the
    batch-parallel ``search_many`` fan-out (all 22 searches concurrent
    against the post-insert snapshot, traces replayed into the shared
    cache) instead of the serial ``search_batch`` scan;
    ``parallel_insert=True`` does the same for the insert wave via the
    two-phase ``insert_many`` (concurrent position seeks on the pre-wave
    snapshot, serialized conflict-aware commits).  ``search_wall_s`` /
    ``insert_wall_s`` record the host wall-clock either way, so the
    modes' engine-side QPS can be compared directly."""
    key = jax.random.PRNGKey(seed)
    s_stats, i_stats, merges = [], [], 0
    recalls = []
    search_fn = eng.search_many if parallel_search else eng.search_batch
    insert_fn = eng.insert_many if parallel_insert else eng.insert_batch
    search_wall = insert_wall = 0.0
    n_searches = n_inserts = 0
    # warm the jits so round-0 wall times are compile-free
    qs0 = query_stream(jax.random.fold_in(key, 10_000), ds["cents"],
                       searches_per_round, noise=ds["noise"])
    jax.block_until_ready(search_fn(state, qs0)[0])
    if inserts_per_round:
        iv0 = insert_stream(jax.random.fold_in(key, 10_001), ds["cents"],
                            inserts_per_round, noise=ds["noise"])
        jax.block_until_ready(insert_fn(state, iv0)[1].store.count)
    for rd in range(rounds):
        kq = jax.random.fold_in(key, 2 * rd)
        ki = jax.random.fold_in(key, 2 * rd + 1)
        newv = insert_stream(ki, ds["cents"], inserts_per_round,
                             noise=ds["noise"], drift=drift)
        t0 = time.time()
        st_i, state = insert_fn(state, newv)
        jax.block_until_ready(state.store.count)
        insert_wall += time.time() - t0
        n_inserts += inserts_per_round
        i_stats.append(st_i)
        if eng.spec.update_path == "buffered" and bool(
                eng.needs_merge(state)):
            mstats, state = eng.merge(state)
            # merge I/O competes with the same window
            i_stats.append(jax.tree.map(lambda x: jnp.asarray(x)[None],
                                        mstats))
            merges += 1
        qs = query_stream(kq, ds["cents"], searches_per_round,
                          noise=ds["noise"])
        t0 = time.time()
        ids, dists, st_s, state = search_fn(state, qs)
        jax.block_until_ready(ids)
        search_wall += time.time() - t0
        n_searches += searches_per_round
        s_stats.append(st_s)
        truth = brute_force_topk(qs, state.store.vectors,
                                 int(state.store.count), 10)
        recalls.append(float(recall_at_k(
            jnp.where(ids >= state.store.n_max, -1, ids), truth)))

    # buffered engines: flush at window end so the merge cost is amortised
    # into the window (the paper averages FreshDiskANN's insertion
    # throughput over time for the same reason)
    if eng.spec.update_path == "buffered" and int(state.buf_count) > 0:
        mstats, state = eng.merge(state)
        i_stats.append(jax.tree.map(lambda x: jnp.asarray(x)[None], mstats))
        merges += 1

    model = open_workload_model(s_stats, i_stats)
    lat = model["search_lat"]
    return dict(
        insert_tput=model["insert_tput"],
        search_qps=model["search_qps"],
        ssd_utilisation=model["rho"],
        search_lat_mean_ms=float(lat.mean() * 1e3),
        search_lat_p90_ms=float(np.percentile(lat, 90) * 1e3),
        search_lat_p99_ms=float(np.percentile(lat, 99) * 1e3),
        recall=float(np.mean(recalls)), merges=merges,
        search_wall_s=search_wall,
        search_wall_qps=n_searches / max(search_wall, 1e-9),
        insert_wall_s=insert_wall,
        insert_wall_qps=n_inserts / max(insert_wall, 1e-9),
        state=state,
    )


def search_only_run(eng, state, ds, *, n_queries: int = 200, seed: int = 1):
    qs = query_stream(jax.random.PRNGKey(seed), ds["cents"], n_queries,
                      noise=ds["noise"])
    ids, dists, stats, state = eng.search_batch(state, qs)
    wall = concurrent_walltime_s([stats], threads=32)
    lats = latencies_s(stats)
    truth = brute_force_topk(qs, state.store.vectors,
                             int(state.store.count), 10)
    return dict(qps=n_queries / wall,
                lat_mean_ms=float(lats.mean() * 1e3),
                recall=float(recall_at_k(ids, truth)),
                hit_rate=float(np.asarray(stats.cache_hits).sum()
                               / max(1, np.asarray(stats.cache_hits).sum()
                                     + np.asarray(stats.cache_misses).sum())),
                state=state)


def fanout_compare(eng, state, ds, *, batch: int = 32, repeats: int = 3,
                   seed: int = 2) -> dict:
    """Wall-clock QPS of the ``search_many`` fan-out vs the sequential
    ``search_batch`` scan on the same snapshot, plus a result-identity
    check.  Both jits are warmed first; best-of-``repeats`` wall times.

    The fan-out's win is engine-side: the scan serialises every query
    through the cache-state thread while vmap runs the whole wave as one
    vectorised program — this is the concurrency the paper's search
    threads exploit, measured here as host throughput."""
    qs = query_stream(jax.random.PRNGKey(seed), ds["cents"], batch,
                      noise=ds["noise"])
    ids_seq, d_seq, *_ = jax.block_until_ready(eng.search_batch(state, qs))
    ids_par, d_par, *_ = jax.block_until_ready(eng.search_many(state, qs))

    def best_wall(fn):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(fn(state, qs)[0])
            best = min(best, time.time() - t0)
        return best

    seq_s = best_wall(eng.search_batch)
    par_s = best_wall(eng.search_many)
    return dict(batch=batch,
                seq_wall_s=seq_s, par_wall_s=par_s,
                seq_qps=batch / seq_s, par_qps=batch / par_s,
                speedup=seq_s / par_s,
                identical=bool((ids_seq == ids_par).all()) and
                bool((d_seq == d_par).all()))


def insert_wave_compare(eng, state, ds, *, batch: int = 16,
                        repeats: int = 3, seed: int = 4,
                        drift: float = 0.3) -> dict:
    """Insert QPS of the two-phase ``insert_many`` fan-out vs the
    sequential ``insert_batch`` scan on the same wave from the same
    snapshot, plus final-graph agreement (count, held-out probe recall).

    The headline QPS numbers come from the SSD cost model over each
    path's exact per-insert counters — the repo's standard measurement.
    The sequential scan is one update thread issuing back-to-back: its
    wave time is the *sum* of per-insert latencies
    (``concurrent_walltime_s(threads=1)``).  The fan-out overlaps every
    insert's position-seek rounds on the device and serialises only the
    tiny structural commits, so its wave time is the device-service
    bound vs the slowest single insert
    (``concurrent_walltime_s(threads=batch)``) — charged on the
    fan-out's own counters, which include the conflict RMW re-reads the
    scan never pays.  Host wall-clocks for both paths are reported as
    secondary engine-side metrics (the vmap win there shows up at
    realistic dimensionalities, not toy corpora)."""
    wave = insert_stream(jax.random.PRNGKey(seed), ds["cents"], batch,
                         noise=ds["noise"], drift=drift)
    stats_m, st_m = eng.insert_many(state, wave)
    stats_s, st_s = eng.insert_batch(state, wave)
    jax.block_until_ready((st_m.store.count, st_s.store.count))

    seq_t = concurrent_walltime_s([stats_s], threads=1)
    fan_t = concurrent_walltime_s([stats_m], threads=batch)

    def best_wall(fn):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(fn(state, wave)[1].store.count)
            best = min(best, time.time() - t0)
        return best

    seq_wall = best_wall(eng.insert_batch)
    par_wall = best_wall(eng.insert_many)

    from repro.core import brute_force_topk, recall_at_k
    qs = query_stream(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                      ds["cents"], 50, noise=ds["noise"])
    truth = brute_force_topk(qs, st_s.store.vectors,
                             int(st_s.store.count), 10)

    def probe(st):
        ids, _, _, _ = eng.search_batch(st, qs)
        return float(recall_at_k(ids, truth))

    return dict(batch=batch,
                seq_insert_qps=batch / seq_t,
                fanout_insert_qps=batch / fan_t,
                speedup=seq_t / fan_t,
                # the wave's concurrency surcharge: snapshot-cache misses
                # the warmer sequential cache would have hit, plus the
                # conflict RMW re-reads
                extra_read_requests=int(
                    np.asarray(stats_m.read_requests).sum()
                    - np.asarray(stats_s.read_requests).sum()),
                seq_wall_s=seq_wall, par_wall_s=par_wall,
                seq_wall_qps=batch / seq_wall,
                fanout_wall_qps=batch / par_wall,
                wall_speedup=seq_wall / par_wall,
                count_equal=bool(int(st_m.store.count) ==
                                 int(st_s.store.count)),
                recall_fanout=probe(st_m), recall_seq=probe(st_s))


def write_json(relpath: str, obj) -> str:
    """Dump ``obj`` under experiments/<relpath> (benchmark JSON output)."""
    import json
    import os
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "experiments", relpath)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, sort_keys=True)
    return path


def fmt_row(name: str, **kv) -> str:
    parts = [name] + [f"{k}={v:.4g}" if isinstance(v, float) else
                      f"{k}={v}" for k, v in kv.items()]
    return ",".join(parts)
