"""Fig 10–12: concurrent search+insert across all systems and datasets —
insertion throughput, search QPS, mean latency, recall."""
from __future__ import annotations

from benchmarks import common as Cm


def run(ds_name: str | None = None, quick: bool = False) -> list[str]:
    rows = []
    datasets = [ds_name] if ds_name else ["fineweb-like", "deep-like"]
    systems = Cm.SYSTEMS if not quick else ("freshdiskann", "odinann",
                                            "navis")
    for name in datasets:
        base = {}
        for system in systems:
            eng, state, ds = Cm.build_engine(system, name)
            res = Cm.concurrent_run(eng, state, ds,
                                    rounds=5 if quick else 8)
            res.pop("state")
            rows.append(Cm.fmt_row(f"fig10_{name}_{system}", **res))
            base[system] = res
        if "odinann" in base and "navis" in base:
            rows.append(Cm.fmt_row(
                f"fig10_{name}_navis_vs_odinann",
                insert_tput_x=base["navis"]["insert_tput"]
                / max(base["odinann"]["insert_tput"], 1e-9),
                search_qps_x=base["navis"]["search_qps"]
                / base["odinann"]["search_qps"],
                latency_reduction_frac=1 - base["navis"][
                    "search_lat_mean_ms"]
                / base["odinann"]["search_lat_mean_ms"]))
        if "freshdiskann" in base and "navis" in base:
            rows.append(Cm.fmt_row(
                f"fig10_{name}_navis_vs_freshdiskann",
                insert_tput_x=base["navis"]["insert_tput"]
                / max(base["freshdiskann"]["insert_tput"], 1e-9),
                search_qps_x=base["navis"]["search_qps"]
                / max(base["freshdiskann"]["search_qps"], 1e-9)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
