"""Fig 10–12: concurrent search+insert across all systems and datasets —
insertion throughput, search QPS, mean latency, recall.

Also measures the batch-parallel fan-outs: the interleaved workload is
re-run with each round's query wave served by the vmapped ``search_many``
(concurrent readers on a shared snapshot, traces replayed into one cache)
and compared against the sequential ``search_batch`` scan — rows in
``experiments/concurrent/fig10.json`` — and the *mixed* driver
interleaves ``insert_many`` waves (two-phase concurrent updates) with
``search_many`` waves across insert ratios, sweeping the fan-out vs the
sequential scans: insert QPS, search QPS and latency per ratio, plus the
insert-wave scaling (fan-out vs sequential wall QPS per batch size) and a
512-insert wave recall-parity check land in
``experiments/concurrent/fig11.json``.

``python -m benchmarks.concurrent --smoke`` runs the mixed driver alone
on a CI-scale corpus (the collection-gated smoke step of scripts/ci.sh).
"""
from __future__ import annotations

import sys

from benchmarks import common as Cm


def run(ds_name: str | None = None, quick: bool = False) -> list[str]:
    rows = []
    blob: dict = {"systems": {}, "fanout": {}}
    datasets = [ds_name] if ds_name else ["fineweb-like", "deep-like"]
    systems = Cm.SYSTEMS if not quick else ("freshdiskann", "odinann",
                                            "navis")
    for name in datasets:
        base = {}
        navis_built = None
        for system in systems:
            eng, state, ds = Cm.build_engine(system, name)
            if system == "navis":
                navis_built = (eng, state, ds)     # reused by fan-out below
            res = Cm.concurrent_run(eng, state, ds,
                                    rounds=5 if quick else 8)
            res.pop("state")
            rows.append(Cm.fmt_row(f"fig10_{name}_{system}", **res))
            base[system] = res
            blob["systems"][f"{name}/{system}"] = res
        if "odinann" in base and "navis" in base:
            rows.append(Cm.fmt_row(
                f"fig10_{name}_navis_vs_odinann",
                insert_tput_x=base["navis"]["insert_tput"]
                / max(base["odinann"]["insert_tput"], 1e-9),
                search_qps_x=base["navis"]["search_qps"]
                / base["odinann"]["search_qps"],
                latency_reduction_frac=1 - base["navis"][
                    "search_lat_mean_ms"]
                / base["odinann"]["search_lat_mean_ms"]))
        if "freshdiskann" in base and "navis" in base:
            rows.append(Cm.fmt_row(
                f"fig10_{name}_navis_vs_freshdiskann",
                insert_tput_x=base["navis"]["insert_tput"]
                / max(base["freshdiskann"]["insert_tput"], 1e-9),
                search_qps_x=base["navis"]["search_qps"]
                / max(base["freshdiskann"]["search_qps"], 1e-9)))

        # -- batch-parallel fan-out vs sequential scan --------------------
        eng, state, ds = navis_built or Cm.build_engine("navis", name)
        par = Cm.concurrent_run(eng, state, ds, rounds=5 if quick else 8,
                                parallel_search=True)
        par.pop("state")
        seq = base.get("navis") or par
        delta = (par["search_wall_qps"]
                 / max(seq["search_wall_qps"], 1e-9))
        rows.append(Cm.fmt_row(
            f"fig10_{name}_navis_parallel_waves",
            search_wall_qps=par["search_wall_qps"],
            seq_search_wall_qps=seq["search_wall_qps"],
            wall_qps_x=delta, recall=par["recall"]))
        blob["systems"][f"{name}/navis_parallel"] = par

        fan = {}
        for batch in ([16] if quick else [16, 32, 64]):
            cmp_ = Cm.fanout_compare(eng, state, ds, batch=batch,
                                     repeats=2 if quick else 3)
            rows.append(Cm.fmt_row(f"fanout_{name}_b{batch}", **cmp_))
            fan[f"b{batch}"] = cmp_
        blob["fanout"][name] = fan

    path = Cm.write_json("concurrent/fig10.json", blob)
    rows.append(f"# wrote {path}")
    rows += run_fig11(ds_name, quick=quick)
    return rows


def run_fig11(ds_name: str | None = None, quick: bool = False,
              smoke: bool = False) -> list[str]:
    """Mixed search+insert fan-out driver (insert_many × search_many).

    Three sections land in ``experiments/concurrent/fig11.json``:

    * ``insert_scaling`` — wall-clock insert QPS, ``insert_many`` fan-out
      vs sequential ``insert_batch``, per wave size (expected ≥1× from
      batch 8 up: the whole wave position-seeks as one vectorised program
      while only the structural commits serialise).
    * ``mixed`` — the interleaved workload at several insert ratios,
      fan-out waves vs sequential scans: modelled insert/search QPS and
      search latency, wall-clock QPS of both phases, recall.
    * ``wave512`` — a ≥512-insert wave: the fan-out graph's held-out
      recall must sit within one point of the sequential graph's (full
      runs only — tests/test_insert_many.py covers it at CI scale).
    """
    rows: list[str] = []
    blob: dict = {"insert_scaling": {}, "mixed": {}, "wave512": {}}
    if smoke:
        datasets = ["smoke"]
        batches, ratios, rounds, repeats = [8, 16], (0.25, 0.75), 2, 2
    else:
        datasets = [ds_name] if ds_name else ["deep-like"]
        batches = [8, 16] if quick else [8, 16, 32, 64]
        ratios = (0.25, 0.75) if quick else (0.2, 0.5, 0.8)
        rounds, repeats = (3, 2) if quick else (6, 3)

    for name in datasets:
        eng, state, ds = Cm.build_engine("navis", name)

        for batch in batches:
            cmp_ = Cm.insert_wave_compare(eng, state, ds, batch=batch,
                                          repeats=repeats)
            rows.append(Cm.fmt_row(f"fig11_{name}_insert_b{batch}", **cmp_))
            blob["insert_scaling"][f"{name}/b{batch}"] = cmp_

        ops = 32
        for ratio in ratios:
            n_ins = max(int(round(ops * ratio)), 1)
            n_srch = max(ops - n_ins, 1)
            kw = dict(rounds=rounds, searches_per_round=n_srch,
                      inserts_per_round=n_ins)
            par = Cm.concurrent_run(eng, state, ds, parallel_search=True,
                                    parallel_insert=True, **kw)
            par.pop("state")
            seq = Cm.concurrent_run(eng, state, ds, **kw)
            seq.pop("state")
            entry = {"fanout": par, "sequential": seq,
                     "insert_ratio": ratio}
            blob["mixed"][f"{name}/r{ratio}"] = entry
            rows.append(Cm.fmt_row(
                f"fig11_{name}_mixed_r{ratio}",
                insert_tput=par["insert_tput"],
                search_qps=par["search_qps"],
                search_lat_mean_ms=par["search_lat_mean_ms"],
                insert_wall_x=par["insert_wall_qps"]
                / max(seq["insert_wall_qps"], 1e-9),
                search_wall_x=par["search_wall_qps"]
                / max(seq["search_wall_qps"], 1e-9),
                recall=par["recall"], seq_recall=seq["recall"]))

        if not (quick or smoke):
            import jax
            import numpy as np
            from repro.data import insert_stream, query_stream
            from repro.core import brute_force_topk, recall_at_k
            wave = insert_stream(jax.random.PRNGKey(11), ds["cents"], 512,
                                 noise=ds["noise"], drift=0.2)
            _, st_m = eng.insert_many(state, wave)
            _, st_s = eng.insert_batch(state, wave)
            qs = query_stream(jax.random.PRNGKey(12), ds["cents"], 100,
                              noise=ds["noise"])
            truth = brute_force_topk(qs, st_s.store.vectors,
                                     int(st_s.store.count), 10)

            def probe(st):
                ids, _, _, _ = eng.search_batch(st, qs)
                return float(recall_at_k(ids, truth))

            entry = dict(wave=512, recall_fanout=probe(st_m),
                         recall_seq=probe(st_s),
                         count_equal=bool(int(st_m.store.count) ==
                                          int(st_s.store.count)))
            blob["wave512"][name] = entry
            rows.append(Cm.fmt_row(f"fig11_{name}_wave512", **entry))

    path = Cm.write_json("concurrent/fig11.json", blob)
    rows.append(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        out = run_fig11(smoke=True)
    elif "--quick" in sys.argv:
        out = run(quick=True)
    else:
        out = run()
    for r in out:
        print(r)
