"""Fig 10–12: concurrent search+insert across all systems and datasets —
insertion throughput, search QPS, mean latency, recall.

Also measures the batch-parallel search fan-out: the interleaved workload
is re-run with each round's query wave served by the vmapped
``search_many`` (concurrent readers on a shared snapshot, traces replayed
into one cache) and compared against the sequential ``search_batch``
scan, engine-side wall-clock QPS on pure search batches included.  All
rows land in ``experiments/concurrent/fig10.json``.
"""
from __future__ import annotations

from benchmarks import common as Cm


def run(ds_name: str | None = None, quick: bool = False) -> list[str]:
    rows = []
    blob: dict = {"systems": {}, "fanout": {}}
    datasets = [ds_name] if ds_name else ["fineweb-like", "deep-like"]
    systems = Cm.SYSTEMS if not quick else ("freshdiskann", "odinann",
                                            "navis")
    for name in datasets:
        base = {}
        navis_built = None
        for system in systems:
            eng, state, ds = Cm.build_engine(system, name)
            if system == "navis":
                navis_built = (eng, state, ds)     # reused by fan-out below
            res = Cm.concurrent_run(eng, state, ds,
                                    rounds=5 if quick else 8)
            res.pop("state")
            rows.append(Cm.fmt_row(f"fig10_{name}_{system}", **res))
            base[system] = res
            blob["systems"][f"{name}/{system}"] = res
        if "odinann" in base and "navis" in base:
            rows.append(Cm.fmt_row(
                f"fig10_{name}_navis_vs_odinann",
                insert_tput_x=base["navis"]["insert_tput"]
                / max(base["odinann"]["insert_tput"], 1e-9),
                search_qps_x=base["navis"]["search_qps"]
                / base["odinann"]["search_qps"],
                latency_reduction_frac=1 - base["navis"][
                    "search_lat_mean_ms"]
                / base["odinann"]["search_lat_mean_ms"]))
        if "freshdiskann" in base and "navis" in base:
            rows.append(Cm.fmt_row(
                f"fig10_{name}_navis_vs_freshdiskann",
                insert_tput_x=base["navis"]["insert_tput"]
                / max(base["freshdiskann"]["insert_tput"], 1e-9),
                search_qps_x=base["navis"]["search_qps"]
                / max(base["freshdiskann"]["search_qps"], 1e-9)))

        # -- batch-parallel fan-out vs sequential scan --------------------
        eng, state, ds = navis_built or Cm.build_engine("navis", name)
        par = Cm.concurrent_run(eng, state, ds, rounds=5 if quick else 8,
                                parallel_search=True)
        par.pop("state")
        seq = base.get("navis") or par
        delta = (par["search_wall_qps"]
                 / max(seq["search_wall_qps"], 1e-9))
        rows.append(Cm.fmt_row(
            f"fig10_{name}_navis_parallel_waves",
            search_wall_qps=par["search_wall_qps"],
            seq_search_wall_qps=seq["search_wall_qps"],
            wall_qps_x=delta, recall=par["recall"]))
        blob["systems"][f"{name}/navis_parallel"] = par

        fan = {}
        for batch in ([16] if quick else [16, 32, 64]):
            cmp_ = Cm.fanout_compare(eng, state, ds, batch=batch,
                                     repeats=2 if quick else 3)
            rows.append(Cm.fmt_row(f"fanout_{name}_b{batch}", **cmp_))
            fan[f"b{batch}"] = cmp_
        blob["fanout"][name] = fan

    path = Cm.write_json("concurrent/fig10.json", blob)
    rows.append(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
