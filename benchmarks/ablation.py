"""Fig 14: component ablation — Layout → +Sel.Vec (CASR) → +Ent.&$ —
insert and concurrent-search throughput."""
from __future__ import annotations

from benchmarks import common as Cm

STEPS = (("layout", "layout_only"), ("sel_vec", "sel_vec"),
         ("ent_cache", "navis"))


def run(ds_name: str = "fineweb-like", quick: bool = False) -> list[str]:
    rows = []
    results = {}
    for label, system in STEPS:
        eng, state, ds = Cm.build_engine(system, ds_name)
        res = Cm.concurrent_run(eng, state, ds, rounds=4 if quick else 7)
        res.pop("state")
        results[label] = res
        rows.append(Cm.fmt_row(f"fig14_{label}",
                               insert_tput=res["insert_tput"],
                               search_qps=res["search_qps"],
                               recall=res["recall"]))
    rows.append(Cm.fmt_row(
        "fig14_gains",
        selvec_insert_x=results["sel_vec"]["insert_tput"]
        / results["layout"]["insert_tput"],
        selvec_search_x=results["sel_vec"]["search_qps"]
        / results["layout"]["search_qps"],
        entcache_insert_x=results["ent_cache"]["insert_tput"]
        / results["sel_vec"]["insert_tput"],
        entcache_search_x=results["ent_cache"]["search_qps"]
        / results["sel_vec"]["search_qps"]))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
