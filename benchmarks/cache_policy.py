"""Fig 17: (a) search-only QPS at several |E_search|; (b) cache-policy
hit rates at a forced-small cache (NAVIS vs LRU/CLOCK/LFU, and NAVIS
without the dynamic entrance graph)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as Cm
from repro.data import insert_stream, query_stream


def run(ds_name: str = "fineweb-like", quick: bool = False) -> list[str]:
    rows = []
    # (a) search-only sweep over E_search
    for e_search in ((24, 40) if quick else (24, 40, 64)):
        for system in ("odinann", "navis"):
            eng, state, ds = Cm.build_engine(system, ds_name,
                                             e_search=e_search)
            res = Cm.search_only_run(eng, state, ds,
                                     n_queries=100 if quick else 200)
            rows.append(Cm.fmt_row(f"fig17a_{system}_es{e_search}",
                                   qps=res["qps"], recall=res["recall"]))

    # (b) hit rates under a small cache after a drifted insert phase
    small = 48                                # forced-small capacity (pages)
    policies = [("navis", "navis", "dynamic"),
                ("navis_wo_ent", "navis", "static"),
                ("lru", "lru", "dynamic"),
                ("clock", "clock", "dynamic"),
                ("lfu", "lfu", "dynamic")]
    for label, policy, entrance in policies:
        eng, state, ds = Cm.build_engine(
            "navis", ds_name, cache_policy=policy, entrance=entrance,
            cache_capacity_pages=small)
        key = jax.random.PRNGKey(17)
        newv = insert_stream(key, ds["cents"], 40 if quick else 100,
                             noise=ds["noise"], drift=0.3)
        _, state = eng.insert_batch(state, newv)
        # warm, then measure
        qs = query_stream(jax.random.fold_in(key, 1), ds["cents"],
                          100 if quick else 200, noise=ds["noise"])
        _, _, _, state = eng.search_batch(state, qs)
        h0 = int(state.ctr_search.cache_hits)
        m0 = int(state.ctr_search.cache_misses)
        qs2 = query_stream(jax.random.fold_in(key, 2), ds["cents"],
                           100 if quick else 200, noise=ds["noise"])
        _, _, _, state = eng.search_batch(state, qs2)
        h = int(state.ctr_search.cache_hits) - h0
        m = int(state.ctr_search.cache_misses) - m0
        rows.append(Cm.fmt_row(f"fig17b_{label}",
                               hit_rate=h / max(h + m, 1)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
