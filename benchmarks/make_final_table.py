"""Regenerate the final §Roofline table (markdown) from the dry-run dir."""
import sys
from pathlib import Path


def main():
    from benchmarks.roofline import table
    rows = table()
    out = ["| arch | shape | dominant | compute_s | memory_s | coll_s | useful | mfu_bound |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant']} | "
            f"{r['compute_s']:.3g} | {r['memory_s']:.3g} | "
            f"{r['collective_s']:.3g} | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']:.4f} |")
    text = "\n".join(out) + "\n"
    Path("experiments/roofline_final.md").write_text(text)
    print(text)


if __name__ == "__main__":
    main()
