"""One hillclimb iteration: re-lower a cell, re-analyze, log the delta.

    PYTHONPATH=src python -m benchmarks.perf_iter \
        --cell hymba-1.5b:train_4k --note "pin scan sharding + bf16 stack"

Runs launch/dryrun.py in a subprocess (fresh 512-device jax), re-parses
the dumped HLO, appends {note, terms} to experiments/perf/<cell>.jsonl and
prints the delta against the previous entry — the §Perf log's raw data.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

PERF_DIR = Path("experiments/perf")


def run_cell(arch: str, shape: str, mesh: str = "pod16x16") -> dict:
    env = dict(os.environ, PYTHONPATH="src")
    args = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
            "--shape", shape, "--dump-hlo", "--out", "experiments/dryrun"]
    if mesh == "pod2x16x16":
        args.append("--multi-pod")
    t0 = time.time()
    out = subprocess.run(args, env=env, capture_output=True, text=True,
                         timeout=1800)
    if out.returncode != 0:
        tail = "\n".join(out.stdout.splitlines()[-5:])
        raise RuntimeError(f"dryrun failed:\n{tail}\n{out.stderr[-2000:]}")
    from benchmarks.roofline import cell_roofline
    r = cell_roofline(f"{arch}__{shape}__{mesh}")
    r["relower_s"] = round(time.time() - t0, 1)
    return r


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--note", required=True)
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args(argv)
    arch, shape = args.cell.split(":")

    r = run_cell(arch, shape, args.mesh)
    entry = {"note": args.note, "ts": time.strftime("%H:%M:%S"),
             **{k: r[k] for k in ("compute_s", "memory_s", "collective_s",
                                  "dominant", "useful_ratio", "mfu_bound",
                                  "flops", "hbm_bytes", "coll_bytes")}}

    PERF_DIR.mkdir(parents=True, exist_ok=True)
    log = PERF_DIR / f"{arch}__{shape}.jsonl"
    prev = None
    if log.exists():
        lines = log.read_text().strip().splitlines()
        if lines:
            prev = json.loads(lines[-1])
    with open(log, "a") as f:
        f.write(json.dumps(entry) + "\n")

    print(f"== {args.cell} [{args.note}] ==")
    for k in ("compute_s", "memory_s", "collective_s", "mfu_bound"):
        line = f"  {k:14s} {entry[k]:.4g}"
        if prev:
            delta = (entry[k] / prev[k] - 1.0) if prev[k] else 0.0
            line += f"   ({delta:+.1%} vs prev)"
        print(line)
    print(f"  dominant: {entry['dominant']}, useful={entry['useful_ratio']:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
