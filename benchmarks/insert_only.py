"""Fig 13: insert-only throughput + latency percentiles + time breakdown
(incl. the entrance-update share, expected <1%)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common as Cm
from repro.data import insert_stream


def run(ds_name: str = "fineweb-like", quick: bool = False) -> list[str]:
    rows = []
    n_ins = 60 if quick else 100
    for system in (("odinann", "navis") if quick else
                   ("odinann", "odinann_cache", "navis")):
        eng, state, ds = Cm.build_engine(system, ds_name)
        newv = insert_stream(jax.random.PRNGKey(5), ds["cents"], n_ins,
                             noise=ds["noise"])
        stats, state = eng.insert_batch(state, newv)
        wall = Cm.concurrent_walltime_s([stats], threads=32)
        lats = Cm.latencies_s(stats) * 1e3
        rows.append(Cm.fmt_row(
            f"fig13a_{system}", insert_tput=n_ins / wall,
            lat_p50_ms=float(np.percentile(lats, 50)),
            lat_p90_ms=float(np.percentile(lats, 90)),
            lat_p99_ms=float(np.percentile(lats, 99))))

        if system == "navis":
            # breakdown: position-seek reads vs structural writes vs
            # entrance update (pure in-memory compute — measure its CPU
            # share directly on the jitted navis_update path)
            rb = np.asarray(stats.read_bytes, np.float64).sum()
            wb = np.asarray(stats.write_bytes, np.float64).sum()
            rounds = np.asarray(stats.serial_rounds, np.float64).sum()
            seek_t = rounds * Cm.SSD.request_latency + rb / Cm.SSD.read_bw
            struct_t = wb / Cm.SSD.write_bw
            # entrance update ~ r_ent sym-PQ rows of compute: model at
            # 1e9 lookup-adds/s host speed
            m = eng.spec.pq_m
            ent_ops = eng.spec.r_ent * (eng.spec.r_ent + 1) * m * n_ins
            ent_t = ent_ops / 1e9
            total = seek_t + struct_t + ent_t
            rows.append(Cm.fmt_row(
                "fig13b_breakdown_navis",
                position_seek_share=float(seek_t / total),
                structural_share=float(struct_t / total),
                ent_update_share=float(ent_t / total)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
