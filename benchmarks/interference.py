"""Fig 3: (a) search interference under concurrent updates (OdinANN);
(b) update-latency breakdown — position seeking vs structural update;
(c) the same mixed search+insert workload on NAVIS served by the
batch-parallel fan-outs (``insert_many`` + ``search_many`` waves) vs the
sequential scans — the engine-side concurrency the paper's update threads
exploit once position seeking overlaps."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as Cm
from repro.data import insert_stream


def run(ds_name: str = "fineweb-like", quick: bool = False) -> list[str]:
    rows = []
    eng, state, ds = Cm.build_engine("odinann", ds_name)

    only = Cm.search_only_run(eng, state, ds, n_queries=100 if quick else 200)
    conc = Cm.concurrent_run(eng, only["state"], ds,
                             rounds=5 if quick else 8)
    drop = 1.0 - conc["search_qps"] / only["qps"]
    rows.append(Cm.fmt_row("fig3a_interference",
                           search_only_qps=only["qps"],
                           concurrent_qps=conc["search_qps"],
                           qps_drop_frac=drop))

    # (b) breakdown: position-seek I/O time vs structural-update write time
    newv = insert_stream(jax.random.PRNGKey(3), ds["cents"],
                         20 if quick else 50, noise=ds["noise"])
    stats, _ = eng.insert_batch(conc["state"], newv)
    rb = np.asarray(stats.read_bytes, np.float64)
    wb = np.asarray(stats.write_bytes, np.float64)
    rounds = np.asarray(stats.serial_rounds, np.float64)
    seek_t = rounds * Cm.SSD.request_latency + rb / Cm.SSD.read_bw
    struct_t = wb / Cm.SSD.write_bw + np.asarray(
        stats.write_requests, np.float64) / Cm.SSD.write_iops
    share = float(seek_t.sum() / (seek_t.sum() + struct_t.sum()))
    rows.append(Cm.fmt_row("fig3b_breakdown",
                           position_seek_share=share,
                           structural_share=1.0 - share))

    # (c) mixed fan-out waves vs sequential scans (NAVIS): overlapping the
    # read-heavy position seeks across the insert wave lifts engine-side
    # throughput of BOTH streams without changing results
    eng_n, state_n, _ = Cm.build_engine("navis", ds_name)
    kw = dict(rounds=3 if quick else 5)
    seq = Cm.concurrent_run(eng_n, state_n, ds, **kw)
    par = Cm.concurrent_run(eng_n, state_n, ds, parallel_search=True,
                            parallel_insert=True, **kw)
    rows.append(Cm.fmt_row(
        "fig3c_fanout_mixed",
        insert_wall_x=par["insert_wall_qps"]
        / max(seq["insert_wall_qps"], 1e-9),
        search_wall_x=par["search_wall_qps"]
        / max(seq["search_wall_qps"], 1e-9),
        fanout_recall=par["recall"], seq_recall=seq["recall"]))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
