#!/usr/bin/env sh
# Tier-1 CI: CPU-only, offline, collection-strict.
#
# Fails on the first error *including* module collection errors (a module
# that fails to import is a hard failure, not a skip) — pytest exits
# non-zero on collection errors, and --strict-markers turns unknown
# marks (typo'd @pytest.mark.slow etc.) into errors too.
#
# Tier-1 collects every tests/test_*.py, including the fan-out suites
# (tests/test_search_many.py, tests/test_insert_many.py).  After the
# suite, the collection-gated smoke step drives the mixed
# search+insert fan-out benchmark end-to-end at CI scale (writes
# experiments/concurrent/fig11.json).
set -eu
cd "$(dirname "$0")/.."

python -m pytest --collect-only -q >/dev/null   # collection gate
python -m pytest --strict-markers -q "$@"

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.concurrent --smoke

# Kernel dispatch parity (interpret-mode Pallas vs the jnp oracles the
# off-TPU engine runs) + traversal-state scaling (hashed visited sets must
# be flat in n_max); both exit non-zero on violation.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.kernel_parity
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.footprint --state-scaling

# Churn smoke (maintenance subsystem): delete+insert cycles with
# consolidation on — exits non-zero if any insert drops, recall degrades
# beyond tolerance of the fresh-build baseline, or live-vertex search
# results change across a consolidation pass.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.churn --smoke
