#!/usr/bin/env sh
# Tier-1 CI: CPU-only, offline, collection-strict.
#
# Fails on the first error *including* module collection errors (a module
# that fails to import is a hard failure, not a skip) — pytest exits
# non-zero on collection errors, and --strict-markers turns unknown
# marks (typo'd @pytest.mark.slow etc.) into errors too.
set -eu
cd "$(dirname "$0")/.."

python -m pytest --collect-only -q >/dev/null   # collection gate
python -m pytest --strict-markers -q "$@"
