from repro.data.pipeline import (TokenStream, insert_stream, make_clustered,
                                 query_stream)

__all__ = ["TokenStream", "insert_stream", "make_clustered", "query_stream"]
