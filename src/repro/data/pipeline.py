"""Deterministic synthetic data pipelines (LM tokens + GVS vector streams).

Stateless-by-construction: batch ``t`` of shard ``s`` is a pure function of
``(seed, t, s)`` via ``jax.random.fold_in``, so

* resuming from a checkpoint replays the exact stream with no iterator
  state to persist,
* every data-parallel host generates only its shard (no sharded-file
  bookkeeping), and
* a straggling/failed batch can be regenerated idempotently — the
  straggler path in launch/train.py retries ``make_batch`` with the same
  (step, shard) and gets bit-identical data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TokenStream:
    """Markov-ish synthetic LM data: structured enough that a model trains
    (loss strictly decreases), cheap enough to generate on the fly."""

    vocab_size: int
    seq_len: int
    batch: int                      # per-shard batch
    seed: int = 0
    n_shards: int = 1

    def make_batch(self, step: int, shard: int = 0) -> dict:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), shard)
        k1, k2 = jax.random.split(key)
        # low-order structure: tokens follow t[i+1] = (31*t[i] + 17 + n) % V
        base = jax.random.randint(k1, (self.batch,), 0,
                                  self.vocab_size, jnp.int32)
        noise = jax.random.randint(k2, (self.seq_len, self.batch), 0, 7,
                                   jnp.int32)

        def scan_tok(t, n):
            nxt = (t * 31 + 17 + n) % self.vocab_size
            return nxt, nxt

        _, toks = jax.lax.scan(scan_tok, base, noise)        # [S, B]
        return {"tokens": jnp.swapaxes(toks, 0, 1)}          # [B, S]

    def global_batch(self, step: int) -> dict:
        """All shards concatenated (single-host runs)."""
        parts = [self.make_batch(step, s) for s in range(self.n_shards)]
        return {k: jnp.concatenate([p[k] for p in parts])
                for k in parts[0]}


# ---------------------------------------------------------------------------
# GVS vector streams
# ---------------------------------------------------------------------------

def make_clustered(key: jax.Array, n: int, dim: int, *, n_clusters: int = 32,
                   scale: float = 3.0, noise: float = 1.0):
    """Clustered-Gaussian corpus (the synthetic stand-in for FineWeb/
    MSMARCO/DEEP embeddings).  Returns (vectors [n, dim], assignments)."""
    kc, kv, ka = jax.random.split(key, 3)
    cents = jax.random.normal(kc, (n_clusters, dim), jnp.float32) * scale
    assign = jax.random.randint(ka, (n,), 0, n_clusters)
    vecs = cents[assign] + noise * jax.random.normal(kv, (n, dim), jnp.float32)
    return vecs, assign, cents


def query_stream(key: jax.Array, cents: jax.Array, n: int, *,
                 noise: float = 1.0) -> jax.Array:
    """Queries drawn from the same cluster mixture as the corpus."""
    ka, kv = jax.random.split(key)
    assign = jax.random.randint(ka, (n,), 0, cents.shape[0])
    return cents[assign] + noise * jax.random.normal(kv, (n, cents.shape[1]), jnp.float32)


def insert_stream(key: jax.Array, cents: jax.Array, n: int, *,
                  noise: float = 1.0, drift: float = 0.0) -> jax.Array:
    """Fresh vectors to insert.  ``drift`` shifts the cluster mixture —
    the paper's 'newly inserted regions' that a static entrance graph
    drifts away from (§3.2)."""
    ka, kv, kd = jax.random.split(key, 3)
    assign = jax.random.randint(ka, (n,), 0, cents.shape[0])
    shift = drift * jax.random.normal(kd, cents.shape, jnp.float32)
    return (cents + shift)[assign] + noise * jax.random.normal(
        kv, (n, cents.shape[1]), jnp.float32)
