from repro.checkpoint.store import latest_step, load, load_latest, save

__all__ = ["latest_step", "load", "load_latest", "save"]
