"""Fault-tolerant checkpointing: atomic commit, resume, elastic remesh.

Layout on disk::

    <dir>/step_000100/
        shard_00000.npz        flattened leaves (this process's shard)
        MANIFEST.json          step, leaf treedef, shapes/dtypes, n_shards
    <dir>/LATEST               text file naming the last COMMITTED step dir

Commit protocol: write into ``step_X.tmp/``, fsync, rename to ``step_X/``,
then rewrite ``LATEST`` — a crash at any point leaves either the previous
checkpoint or a complete new one (``*.tmp`` dirs are garbage-collected on
the next save).  Elastic remesh: arrays are stored unsharded per leaf, so
``load_latest`` can re-``device_put`` them under any mesh/sharding — a run
checkpointed on mesh A restarts on mesh B (see launch/train.py
``--remesh``)."""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


def save(ckpt_dir: str | Path, step: int, tree: Any, *, keep: int = 3,
         shard: int = 0) -> Path:
    """Atomically persist ``tree`` for ``step``.  Returns the commit dir."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    names, leaves, _ = _leaves_with_paths(tree)
    # store raw uint8 views: numpy's npz cannot round-trip ml_dtypes
    # (bfloat16 comes back as void); the manifest holds dtype + shape
    np_leaves = [np.asarray(x) for x in leaves]
    arrays = {f"leaf_{i:05d}": np.frombuffer(x.tobytes(), np.uint8)
              for i, x in enumerate(np_leaves)}
    np.savez(tmp / f"shard_{shard:05d}.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "names": names,
        "shapes": [list(x.shape) for x in np_leaves],
        "dtypes": [str(x.dtype) for x in np_leaves],
    }
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
    # fsync the shard file then atomically publish
    with open(tmp / f"shard_{shard:05d}.npz", "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    (ckpt_dir / "LATEST.tmp").write_text(final.name)
    (ckpt_dir / "LATEST.tmp").rename(ckpt_dir / "LATEST")

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(d for d in ckpt_dir.iterdir()
                   if d.is_dir() and d.name.startswith("step_")
                   and not d.name.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)
    for d in ckpt_dir.glob("*.tmp"):
        if d.is_dir():
            shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    latest = ckpt_dir / "LATEST"
    if not latest.exists():
        return None
    name = latest.read_text().strip()
    if not (ckpt_dir / name / "MANIFEST.json").exists():
        return None          # torn commit: fall back to scanning
    return int(name.split("_")[1])


def load(ckpt_dir: str | Path, step: int, like: Any, *,
         shard: int = 0, sharding=None) -> Any:
    """Restore the pytree saved at ``step``.  ``like`` supplies the
    treedef; ``sharding`` optionally re-places every leaf (elastic remesh:
    pass NamedShardings for the *new* mesh)."""
    import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy
    ckpt_dir = Path(ckpt_dir)
    d = ckpt_dir / f"step_{step:08d}"
    data = np.load(d / f"shard_{shard:05d}.npz")
    manifest = json.loads((d / "MANIFEST.json").read_text())
    leaves = [
        np.frombuffer(data[f"leaf_{i:05d}"].tobytes(),
                      dtype=np.dtype(manifest["dtypes"][i])).reshape(
                          manifest["shapes"][i])
        for i in range(manifest["n_leaves"])]
    _, like_leaves, treedef = _leaves_with_paths(like)
    assert len(leaves) == len(like_leaves), (
        f"checkpoint has {len(leaves)} leaves, expected {len(like_leaves)}")
    if sharding is not None:
        shard_leaves = jax.tree.leaves(
            sharding, is_leaf=lambda x: hasattr(x, "device_set"))
        out = [jax.device_put(x, s) for x, s in zip(leaves, shard_leaves)]
    else:
        out = [jax.numpy.asarray(x) for x in leaves]
    return jax.tree_util.tree_unflatten(treedef, out)


def load_latest(ckpt_dir: str | Path, like: Any, *, shard: int = 0,
                sharding=None):
    """(step, tree) of the newest committed checkpoint, or (None, None)."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return step, load(ckpt_dir, step, like, shard=shard, sharding=sharding)
