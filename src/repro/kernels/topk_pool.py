"""Pallas TPU kernel: candidate-pool merge (partial top-k without sort).

Every traversal hop merges the explored pool [P] with the beam's freshly
scored neighbors [Q] and keeps the P closest (§2.2 ②).  A comparison sort
is a poor fit for the VPU; instead we compute each element's *rank* with
one dense pairwise comparison reduction —

    rank_i = Σ_j [ d_j < d_i  or  (d_j = d_i and j < i) ]

— an [L, L] boolean matrix reduced along rows (L = P + Q ≤ a few hundred,
so the O(L²) mask is a handful of VPU tiles), then scatter each element
whose rank < P to output slot ``rank``.  One pass, no data-dependent
control flow, stable under ties: exactly the semantics of the jnp argsort
oracle in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_kernel(d_ref, ids_ref, out_d_ref, out_ids_ref, *, p: int):
    d = d_ref[...]                                     # [L]
    ids = ids_ref[...]                                 # [L]
    L = d.shape[0]
    di = d[:, None]                                    # [L, 1]
    dj = d[None, :]                                    # [1, L]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    before = (dj < di) | ((dj == di) & (jj < ii))      # [L, L]
    rank = jnp.sum(before.astype(jnp.int32), axis=1)   # [L]

    keep = rank < p
    slot = jnp.where(keep, rank, p)                    # p = drop bin
    out_d = jnp.full((p + 1,), jnp.float32(3.4e38))
    out_i = jnp.full((p + 1,), jnp.int32(-1))
    out_d = out_d.at[slot].set(jnp.where(keep, d, out_d[slot]))
    out_i = out_i.at[slot].set(jnp.where(keep, ids, out_i[slot]))
    out_d_ref[...] = out_d[:p]
    out_ids_ref[...] = out_i[:p]


def pool_merge_pallas(pool_d: jax.Array, pool_ids: jax.Array,
                      new_d: jax.Array, new_ids: jax.Array, *,
                      interpret: bool = True):
    """Merge (pool_d [P], new_d [Q]) keeping the P smallest.

    Returns (d [P], ids [P]) ascending, -1-padded like the pool inputs.
    """
    p = pool_d.shape[0]
    d = jnp.concatenate([pool_d, new_d]).astype(jnp.float32)
    ids = jnp.concatenate([pool_ids, new_ids]).astype(jnp.int32)
    L = d.shape[0]

    out_d, out_ids = pl.pallas_call(
        functools.partial(_merge_kernel, p=p),
        in_specs=[pl.BlockSpec((L,), lambda: (0,)),
                  pl.BlockSpec((L,), lambda: (0,))],
        out_specs=(pl.BlockSpec((p,), lambda: (0,)),
                   pl.BlockSpec((p,), lambda: (0,))),
        out_shape=(jax.ShapeDtypeStruct((p,), jnp.float32),
                   jax.ShapeDtypeStruct((p,), jnp.int32)),
        interpret=interpret,
    )(d, ids)
    return out_d, out_ids
