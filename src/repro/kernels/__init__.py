"""Pallas TPU kernels for the paper's compute hot-spots.

Three kernels, each with a pure-jnp oracle in ref.py and a jitted wrapper
in ops.py (interpret=True off-TPU):

  pq_adc     — ADC LUT distance (traversal's per-hop examination)
  rerank_l2  — grouped exact-L2 rerank = CASR's pipelined compute stage
  topk_pool  — explored-pool merge (partial top-k without sort)
"""
from repro.kernels.ops import adc_distance, pool_merge, rerank_l2

__all__ = ["adc_distance", "pool_merge", "rerank_l2"]
