"""Pallas TPU kernels for the paper's compute hot-spots.

Three kernels, each with a pure-jnp oracle in ref.py and a
backend-dispatched wrapper in ops.py (TPU → Pallas Mosaic; off-TPU → the
oracle, with interpret-mode Pallas opt-in via NAVIS_KERNEL_INTERPRET=1):

  pq_adc     — ADC LUT distance (traversal's per-hop examination)
  rerank_l2  — grouped exact-L2 rerank = CASR's pipelined compute stage
  topk_pool  — explored-pool merge (partial top-k without sort)

The engine's traversal/rerank hot loops (core/search.py, core/casr.py,
core/engine.py) call through these wrappers.
"""
from repro.kernels.ops import adc_distance, pool_merge, rerank_l2

__all__ = ["adc_distance", "pool_merge", "rerank_l2"]
