"""Pallas TPU kernel: grouped exact-L2 rerank (the CASR compute stage).

Computes d[p] = ‖q − x_p‖² over a PQ-ordered candidate matrix, in groups
of ``s`` rows — the TPU materialisation of Algorithm 1's speculative
pipeline.  The paper overlaps group t+1's io_uring submission with group
t's exact-distance compute; here the grid dimension *is* the group index,
and Pallas's automatic pipelining issues block t+1's HBM→VMEM DMA while
block t runs on the VPU/MXU — the same submission/compute overlap,
expressed as BlockSpec streaming (DESIGN.md §2, io_uring row).

The group dimension stays a *grid* axis (not folded into one big block) so
the engine can bound the number of groups it launches: CASR's early stop
truncates the candidate matrix before calling, and the kernel never
touches vectors past the convergence point.

d is computed as ‖q‖² − 2·q·x + ‖x‖² with the q·x term on the MXU
(a [s, D] × [D, 1] matmul per group) — at D ≥ 512 this is ~2× fewer VPU
flops than the subtract-square-reduce form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rerank_kernel(q_ref, x_ref, out_ref):
    q = q_ref[...]                                    # [1, D]
    x = x_ref[...]                                    # [s, D]
    qx = jnp.dot(x, q.T, preferred_element_type=jnp.float32)  # [s, 1] (MXU)
    xx = jnp.sum(x * x, axis=1, keepdims=True)        # [s, 1]
    qq = jnp.sum(q * q, axis=1, keepdims=True)        # [1, 1]
    out_ref[...] = (xx - 2.0 * qx + qq)[:, 0]


def rerank_l2_pallas(q: jax.Array, xs: jax.Array, *, group: int = 8,
                     interpret: bool = True) -> jax.Array:
    """q: [D]; xs: [P, D] candidate vectors (PQ order) -> [P] distances.

    ``group`` is CASR's s: one grid step per group, giving the
    double-buffered load/compute overlap on real TPU hardware.
    """
    p, d = xs.shape
    ng = -(-p // group)
    pad = ng * group - p
    if pad:
        xs = jnp.pad(xs, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        _rerank_kernel,
        grid=(ng,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),        # query pinned
            pl.BlockSpec((group, d), lambda i: (i, 0)),    # groups stream
        ],
        out_specs=pl.BlockSpec((group,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ng * group,), jnp.float32),
        interpret=interpret,
    )(q[None].astype(jnp.float32), xs.astype(jnp.float32))
    return out[:p]
