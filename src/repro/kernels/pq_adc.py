"""Pallas TPU kernel: PQ asymmetric-distance (ADC) lookup-accumulate.

Computes d[b] = Σ_m LUT[m, codes[b, m]] for a query's per-subspace distance
LUT against a block of PQ codes — the inner loop of every traversal hop
(§2.2 ②: neighbor examination uses PQ distances, not full vectors).

TPU adaptation (DESIGN.md §2): the CPU/GPU formulation is a random gather
per (b, m), which maps poorly onto the VPU (no fast per-lane gather from
VMEM tables).  We instead materialise each subspace's selection as a
comparison mask against a broadcasted iota and reduce with a
multiply-accumulate — an elementwise [TB, 256] op that the 8×128 VPU
executes at full width, with zero gathers.  The LUT (M×256 f32 ≤ 128 KiB
for M=128) is pinned whole in VMEM; codes stream through in [TB, M] tiles
via the grid pipeline (block t+1's HBM→VMEM copy overlaps block t's
compute — automatic double buffering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(lut_ref, codes_ref, out_ref, *, m: int):
    codes = codes_ref[...].astype(jnp.int32)          # [TB, M]
    tb = codes.shape[0]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (tb, 256), 1)

    def body(mi, acc):
        sel = (lanes == codes[:, mi][:, None])        # [TB, 256] one-hot
        row = lut_ref[mi, :]                          # [256]
        return acc + jnp.sum(jnp.where(sel, row[None, :], 0.0), axis=1)

    acc = jax.lax.fori_loop(0, m, body, jnp.zeros((tb,), jnp.float32))
    out_ref[...] = acc


def adc_distance_pallas(lut: jax.Array, codes: jax.Array, *,
                        block_b: int = 256,
                        interpret: bool = True) -> jax.Array:
    """lut: [M, 256] f32; codes: [B, M] uint8 -> [B] f32 distances."""
    m = lut.shape[0]
    b = codes.shape[0]
    nb = -(-b // block_b)
    pad = nb * block_b - b
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_adc_kernel, m=m),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, 256), lambda i: (0, 0)),       # LUT pinned
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),   # codes stream
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block_b,), jnp.float32),
        interpret=interpret,
    )(lut.astype(jnp.float32), codes)
    return out[:b]
