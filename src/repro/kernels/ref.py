"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are also what :mod:`repro.kernels.ops` dispatches to off-TPU, so
they are *dtype-preserving*: they compute in the input dtype exactly like
the engine's previous inline jnp (``pq.adc_distance`` / ``pq.exact_l2`` /
stable ``lax.top_k`` merge) — under x64 the engine's distance math stays
float64.  The Pallas kernels themselves emit float32 (TPU VPU/MXU
accumulation dtype); parity checks compare at float32 tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(3.4e38)


def adc_distance_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut: [M, 256]; codes: [B, M] uint8 -> [B]."""
    idx = codes.astype(jnp.int32)
    vals = jnp.take_along_axis(lut, idx.T, axis=1)
    return vals.sum(0)


def rerank_l2_ref(q: jax.Array, xs: jax.Array) -> jax.Array:
    """q: [D]; xs: [P, D] -> [P] squared L2."""
    diff = xs - q[None]
    return jnp.sum(diff * diff, axis=-1)


def pool_merge_ref(pool_d, pool_ids, new_d, new_ids):
    """Keep the P smallest of the concatenation (stable on ties)."""
    p = pool_d.shape[0]
    d = jnp.concatenate([pool_d, new_d])
    ids = jnp.concatenate([pool_ids, new_ids])
    order = jnp.argsort(d, stable=True)[:p]
    return d[order], ids[order]
