"""Backend-dispatched public wrappers for the hot-spot kernels.

The engine's traversal/rerank hot loops call these three ops every hop;
dispatch picks the fastest correct implementation per backend:

==========  ==================================================
backend     implementation
==========  ==================================================
TPU         Pallas Mosaic kernels (pq_adc / rerank_l2 / topk_pool)
off-TPU     the pure-jnp ``ref.py`` oracles (XLA-fused; the
            Pallas *interpreter* is orders of magnitude slower
            and is NOT used unless explicitly requested)
off-TPU +   Pallas interpret mode — opt-in via
``NAVIS_KERNEL_INTERPRET=1``; validates the exact TPU program
            against the oracles (CI parity smoke)
==========  ==================================================

The ref oracles compute the same math as the engine's previous inline
jnp (``pq.adc_distance`` / ``pq.exact_l2`` / stable ``lax.top_k`` merge)
*in the input dtype* (float64 stays float64 under x64), so off-TPU
results match the pre-dispatch engine.

The mode is resolved at trace time (``kernel_mode()`` reads the
environment when a caller is first traced); set the flag before building
engines.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import ref
from repro.kernels.pq_adc import adc_distance_pallas
from repro.kernels.rerank_l2 import rerank_l2_pallas
from repro.kernels.topk_pool import pool_merge_pallas


def kernel_mode() -> str:
    """'mosaic' on TPU, else 'interpret' iff NAVIS_KERNEL_INTERPRET is a
    truthy value, else 'ref'."""
    if jax.default_backend() == "tpu":
        return "mosaic"
    if os.environ.get("NAVIS_KERNEL_INTERPRET", "") not in ("", "0"):
        return "interpret"
    return "ref"


# the ref oracles are NOT jit-wrapped here: engine hot loops call these
# inside their own jit, and an extra jit boundary changes XLA fusion (and
# thus float rounding at the last ulp) versus the previously-inlined jnp —
# inlining keeps the off-TPU engine bit-identical to pre-dispatch.
_adc_ref = ref.adc_distance_ref
_rerank_ref = ref.rerank_l2_ref
_merge_ref = ref.pool_merge_ref


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def _adc_pallas(lut, codes, *, block_b, interpret):
    return adc_distance_pallas(lut, codes, block_b=block_b,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("group", "interpret"))
def _rerank_pallas(q, xs, *, group, interpret):
    return rerank_l2_pallas(q, xs, group=group, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _merge_pallas(pool_d, pool_ids, new_d, new_ids, *, interpret):
    return pool_merge_pallas(pool_d, pool_ids, new_d, new_ids,
                             interpret=interpret)


def adc_distance(lut, codes, *, block_b: int = 256):
    """lut: [M, 256]; codes: [B, M] uint8 -> [B] PQ distances."""
    mode = kernel_mode()
    if mode == "ref":
        return _adc_ref(lut, codes)
    return _adc_pallas(lut, codes, block_b=block_b,
                       interpret=mode == "interpret")


def rerank_l2(q, xs, *, group: int = 8):
    """q: [D]; xs: [P, D] -> [P] exact squared L2."""
    mode = kernel_mode()
    if mode == "ref":
        return _rerank_ref(q, xs)
    return _rerank_pallas(q, xs, group=group, interpret=mode == "interpret")


def pool_merge(pool_d, pool_ids, new_d, new_ids):
    """Merge keeping the |pool| smallest (stable on ties, ascending)."""
    mode = kernel_mode()
    if mode == "ref":
        return _merge_ref(pool_d, pool_ids, new_d, new_ids)
    return _merge_pallas(pool_d, pool_ids, new_d, new_ids,
                         interpret=mode == "interpret")
