"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels execute their bodies in Python via the Pallas interpreter, which
validates the exact TPU program against the ref.py oracles).  On a real
TPU backend the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.pq_adc import adc_distance_pallas
from repro.kernels.rerank_l2 import rerank_l2_pallas
from repro.kernels.topk_pool import pool_merge_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_b",))
def adc_distance(lut, codes, *, block_b: int = 256):
    return adc_distance_pallas(lut, codes, block_b=block_b,
                               interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("group",))
def rerank_l2(q, xs, *, group: int = 8):
    return rerank_l2_pallas(q, xs, group=group, interpret=not _on_tpu())


@jax.jit
def pool_merge(pool_d, pool_ids, new_d, new_ids):
    return pool_merge_pallas(pool_d, pool_ids, new_d, new_ids,
                             interpret=not _on_tpu())
