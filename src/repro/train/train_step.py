"""Train-step builder: loss → grad → (optional compression) → optimizer.

Supports microbatch gradient accumulation (``lax.scan`` so per-microbatch
reduce-scatters overlap the next microbatch's compute on real async-collective
hardware) and optional bf16 gradient compression with error feedback.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import transformer as T
from repro.models.layers import NO_SHARD
from repro.train.optimizer import Optimizer, apply_updates


def make_loss_fn(cfg: T.ModelConfig, *, rules=NO_SHARD, mesh=None):
    def loss_fn(params, batch):
        return T.lm_loss(cfg, params, batch["tokens"],
                         cross_src=batch.get("cross_src"), rules=rules,
                         mesh=mesh)
    return loss_fn


def _compress_grads(grads, err):
    """bf16 stochastic-free compression with error feedback.

    The all-reduce itself happens inside autodiff (psum of bf16 leaves); here
    we model the quantise/dequantise + residual-carry that production grad
    compression performs.  Returns (compressed-then-restored grads, new err).
    """
    def comp(g, e):
        g32 = g.astype(jnp.float32) + e
        q = g32.astype(jnp.bfloat16)
        new_e = g32 - q.astype(jnp.float32)
        return q.astype(jnp.float32), new_e
    out = jax.tree.map(comp, grads, err)
    gq = jax.tree.map(lambda o: o[0], out,
                      is_leaf=lambda o: isinstance(o, tuple))
    ne = jax.tree.map(lambda o: o[1], out,
                      is_leaf=lambda o: isinstance(o, tuple))
    return gq, ne


def make_train_step(cfg: T.ModelConfig, optimizer: Optimizer, *,
                    rules=NO_SHARD, mesh=None, microbatches: int = 1,
                    grad_compression: bool = False):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics).  ``batch["tokens"]: [B, S]``."""
    loss_fn = make_loss_fn(cfg, rules=rules, mesh=mesh)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            acc_g = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                 acc_g, g)
            return (acc_loss + l, acc_g), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), _ = lax.scan(body, (jnp.zeros((), jnp.float32), zeros),
                                    micro)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch, step):
        loss, grads = grads_of(params, batch)
        if grad_compression:
            err = opt_state["grad_err"]
            grads, err = _compress_grads(grads, err)
            opt_state = dict(opt_state, grad_err=err)
            inner = opt_state["inner"]
        else:
            inner = opt_state
        updates, inner = optimizer.update(grads, inner, params, step)
        params = apply_updates(params, updates)
        if grad_compression:
            opt_state = dict(opt_state, inner=inner)
        else:
            opt_state = inner
        return params, opt_state, {"loss": loss}

    return train_step


def init_opt_state(cfg: T.ModelConfig, optimizer: Optimizer, params,
                   grad_compression: bool = False):
    inner = optimizer.init(params)
    if not grad_compression:
        return inner
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"inner": inner, "grad_err": err}
