"""Optimizers: AdamW (configurable state dtype) and factored Adafactor.

Functional, pytree-shaped, sharding-aware: ``init_specs`` mirrors a
parameter PartitionSpec tree onto the optimizer state so the dry-run can
declare in_shardings for 480B-parameter states without materialising them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # init_specs(param_specs, param_shapes) -> state PartitionSpec tree
    init_specs: Callable[[Any, Any], Any]


def _tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: float | Callable = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          state_dtype: str = "bfloat16", max_grad_norm: float = 1.0
          ) -> Optimizer:
    dtype = jnp.dtype(state_dtype)
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def init_specs(param_specs, param_shapes=None):
        return {"m": param_specs, "v": param_specs, "count": P()}

    def update(grads, state, params, step):
        if max_grad_norm:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m32 / bc1
            vhat = v32 / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u, m32.astype(dtype), v32.astype(dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda o: isinstance(o, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda o: isinstance(o, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda o: isinstance(o, tuple))
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update, init_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum) — for the ≥100B archs
# ---------------------------------------------------------------------------

def _factored(p_shape) -> bool:
    return len(p_shape) >= 2 and p_shape[-1] > 1 and p_shape[-2] > 1


def adafactor(lr: float | Callable = 1e-3, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer.  The factor state is stored as a
    *list aligned with the flattened parameter order* (not a mirrored dict
    tree): per-leaf dicts inside a mirrored tree would need is_leaf
    sentinels that collide with user parameter names."""
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def _leaf_state(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    def init(params):
        return {"f": [_leaf_state(p) for p in jax.tree.leaves(params)],
                "count": jnp.zeros((), jnp.int32)}

    def init_specs(param_specs, param_shapes):
        # Factor specs follow the parameter spec with the reduced dim dropped.
        specs = jax.tree.leaves(
            param_specs, is_leaf=lambda x: isinstance(x, P) or x is None)
        shapes = jax.tree.leaves(param_shapes)
        out = []
        for spec, shp in zip(specs, shapes):
            spec_t = tuple(spec) if spec is not None else ()
            spec_t = spec_t + (None,) * (len(shp.shape) - len(spec_t))

            def drop(i, s=spec_t):
                s = list(s)
                if len(s) >= abs(i):
                    del s[i]
                return P(*s)

            if _factored(shp.shape):
                out.append({"vr": drop(-1), "vc": drop(-2)})
            else:
                out.append({"v": P(*spec_t)})
        return {"f": out, "count": P()}

    def update(grads, state, params, step):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta = 1.0 - c ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, st, p):
            if "vr" in st:
                # two independent square+reduce expressions so each fuses —
                # never materialise the full fp32 square of a 480B gradient.
                row = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1) + eps
                col = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-2) + eps
                vr = beta * st["vr"] + (1 - beta) * row
                vc = beta * st["vc"] + (1 - beta) * col
                denom = (vr[..., None] / jnp.mean(
                    vr, axis=-1, keepdims=True)[..., None]) * vc[..., None, :]
                new_st = {"vr": vr, "vc": vc}
            else:
                g32 = jnp.square(g.astype(jnp.float32)) + eps
                denom = beta * st["v"] + (1 - beta) * g32
                new_st = {"v": denom}
            u = g.astype(jnp.float32) * jax.lax.rsqrt(denom + eps)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, new_st

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = jax.tree.leaves(params)
        pairs = [upd(g, st, p) for g, st, p
                 in zip(g_leaves, state["f"], p_leaves)]
        updates = treedef.unflatten([o[0] for o in pairs])
        new_f = [o[1] for o in pairs]
        return updates, {"f": new_f, "count": count}

    return Optimizer(init, update, init_specs)


def make_optimizer(name: str, *, state_dtype: str = "bfloat16",
                   lr=None) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr or 3e-4, state_dtype=state_dtype)
    if name == "adafactor":
        return adafactor(lr=lr or 1e-3)
    raise ValueError(f"unknown optimizer {name}")
