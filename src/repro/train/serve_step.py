"""Serving steps: prefill (prompt → cache) and decode (one token)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.layers import NO_SHARD


def make_prefill_step(cfg: T.ModelConfig, *, rules=NO_SHARD, mesh=None,
                      max_seq: int | None = None):
    def prefill_step(params, tokens, cross_src=None):
        return T.prefill_step(cfg, params, tokens, max_seq=max_seq,
                              cross_src=cross_src, rules=rules, mesh=mesh)
    return prefill_step


def make_decode_step(cfg: T.ModelConfig, *, rules=NO_SHARD, mesh=None,
                     sample: bool = False, temperature: float = 1.0):
    def decode_step(params, cache, tokens, pos, rng=None):
        logits, cache = T.decode_step(cfg, params, cache, tokens, pos,
                                      rules=rules, mesh=mesh)
        if sample:
            next_tok = jax.random.categorical(rng, logits / temperature)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), logits, cache
    return decode_step
