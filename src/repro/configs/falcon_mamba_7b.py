"""falcon-mamba-7b — attention-free Mamba-1 [arXiv:2410.05355].

64L d_model=4096, d_inner=8192, ssm_state=16, dt_rank=256, vocab=65024.
No attention, no separate MLP: each layer is a Mamba mixer block.
long_500k runs (O(1) recurrent state).
"""
from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig, uniform_pattern

MODEL = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1, d_ff=0,
    vocab_size=65024,
    patterns=uniform_pattern("mamba", 64),
    ssm_state=16, d_inner=8192, dt_rank=256, conv_kernel=4,
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke", family="ssm",
    num_layers=3, d_model=64, num_heads=1, num_kv_heads=1, d_ff=0,
    vocab_size=512,
    patterns=uniform_pattern("mamba", 3),
    ssm_state=8, d_inner=128, dt_rank=16, conv_kernel=4,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="falcon-mamba-7b", model=MODEL, smoke=SMOKE,
    source="arXiv:2410.05355",
)
