"""gemma-2b — dense MQA with GeGLU and head_dim=256 [arXiv:2403.08295].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ArchSpec
from repro.models.transformer import ModelConfig, uniform_pattern

MODEL = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, d_ff=16384,
    vocab_size=256000, head_dim=256,
    patterns=uniform_pattern("attn", 18),
    activation="gelu", glu=True, norm_plus_one=True, embed_scale=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, d_ff=128,
    vocab_size=512, head_dim=32,
    patterns=uniform_pattern("attn", 2),
    activation="gelu", glu=True, norm_plus_one=True, embed_scale=True,
    tie_embeddings=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="gemma-2b", model=MODEL, smoke=SMOKE,
    source="arXiv:2403.08295",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
)
