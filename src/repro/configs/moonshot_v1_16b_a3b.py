"""moonshot-v1-16b-a3b — Moonlight 16B-A3B MoE [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=163840,
MoE 64 experts top-6.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ArchSpec
from repro.models.transformer import ModelConfig, uniform_pattern

MODEL = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16, d_ff=1408,
    vocab_size=163840,
    patterns=uniform_pattern("attn", 48),
    moe_experts=64, moe_top_k=6, moe_d_ff=1408,
    activation="silu", glu=True,
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=32,
    vocab_size=512,
    patterns=uniform_pattern("attn", 2),
    moe_experts=8, moe_top_k=2, moe_d_ff=32,
    activation="silu", glu=True,
    param_dtype="float32", capacity_factor=8.0,
)

ARCH = ArchSpec(
    arch_id="moonshot-v1-16b-a3b", model=MODEL, smoke=SMOKE,
    source="hf:moonshotai/Moonlight-16B-A3B",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
)
