"""Architecture registry: ``--arch <id>`` resolution + per-cell input specs.

Every assigned architecture is importable here; ``get_arch`` accepts the
dashed public id.  ``input_specs`` builds the ShapeDtypeStruct stand-ins for
a (arch × shape) dry-run cell — weak-type-correct, shardable, and never
allocating device memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeSpec, SHAPES  # noqa: F401
from repro.configs import (  # noqa: F401
    arctic_480b,
    falcon_mamba_7b,
    gemma3_1b,
    gemma_2b,
    hymba_1_5b,
    llama_3_2_vision_90b,
    moonshot_v1_16b_a3b,
    qwen1_5_0_5b,
    qwen2_0_5b,
    whisper_medium,
)

_MODULES = (
    hymba_1_5b, moonshot_v1_16b_a3b, arctic_480b, whisper_medium,
    qwen1_5_0_5b, qwen2_0_5b, gemma3_1b, gemma_2b, falcon_mamba_7b,
    llama_3_2_vision_90b,
)

REGISTRY: dict[str, ArchSpec] = {m.ARCH.arch_id: m.ARCH for m in _MODULES}

ARCH_IDS = tuple(REGISTRY)


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(ARCH_IDS)}")
    return REGISTRY[arch_id]


def cells(include_skipped: bool = False):
    """Every assigned (arch × shape) cell, with skip reasons."""
    for arch_id, arch in REGISTRY.items():
        for shape_name, shape in SHAPES.items():
            reason = arch.skip_shapes.get(shape_name)
            if reason is None or include_skipped:
                yield arch_id, shape_name, reason


def input_specs(arch: ArchSpec, shape: ShapeSpec, *, smoke: bool = False,
                rules=None):
    """ShapeDtypeStruct stand-ins for one dry-run cell.

    Returns (kwargs-for-step-fn).  For decode cells the KV cache structs are
    included (they are donated inputs of serve_step).
    """
    from repro.models import transformer as T
    from repro.models.layers import NO_SHARD

    cfg = arch.smoke if smoke else arch.model
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = f((B, S), jnp.int32)
        if cfg.encoder_layers:
            specs["cross_src"] = f((B, cfg.cross_seq, cfg.d_model),
                                   cfg.dtype)
        elif cfg.cross_seq:
            specs["cross_src"] = f((B, cfg.cross_seq, cfg.d_model),
                                   cfg.dtype)
    else:  # decode: one new token against an S-long cache
        specs["tokens"] = f((B, 1), jnp.int32)
        specs["pos"] = f((), jnp.int32)
        specs["cache"] = T.cache_shapes(cfg, B, S, rules or NO_SHARD)
    return specs
