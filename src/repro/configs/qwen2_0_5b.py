"""qwen2-0.5b — dense GQA with QKV bias [arXiv:2407.10671; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ArchSpec
from repro.models.transformer import ModelConfig, uniform_pattern

MODEL = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2, d_ff=4864,
    vocab_size=151936,
    patterns=uniform_pattern("attn", 24),
    qkv_bias=True, tie_embeddings=True,
    activation="silu", glu=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512,
    patterns=uniform_pattern("attn", 2),
    qkv_bias=True, tie_embeddings=True,
    activation="silu", glu=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="qwen2-0.5b", model=MODEL, smoke=SMOKE,
    source="arXiv:2407.10671",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
)
