"""arctic-480b — Snowflake Arctic dense-MoE hybrid [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864, MoE 128 experts top-2 with a
parallel dense FFN residual, vocab=32000.  ~480B total parameters; trained
here with Adafactor (factored second moment) so optimizer state fits the
single-pod 16 GB/chip HBM budget (see DESIGN.md §5).
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ArchSpec
from repro.models.transformer import ModelConfig, uniform_pattern

MODEL = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8, d_ff=4864,
    vocab_size=32000,
    patterns=uniform_pattern("attn", 35),
    moe_experts=128, moe_top_k=2, moe_d_ff=4864, moe_dense_residual=True,
    activation="silu", glu=True,
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, d_ff=64,
    vocab_size=512,
    patterns=uniform_pattern("attn", 2),
    moe_experts=8, moe_top_k=2, moe_d_ff=64, moe_dense_residual=True,
    activation="silu", glu=True,
    param_dtype="float32", capacity_factor=8.0,
)

ARCH = ArchSpec(
    arch_id="arctic-480b", model=MODEL, smoke=SMOKE,
    optimizer="adafactor",
    source="hf:Snowflake/snowflake-arctic-base",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
)
