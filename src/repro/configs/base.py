"""Architecture + input-shape specification types shared by all configs."""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


# The four assigned LM shapes.  ``decode_*`` / ``long_*`` lower serve_step
# (one new token against a seq_len KV cache), not train_step.
SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

FULL_ATTENTION_SKIP = ("pure full-attention architecture: a 500k-token dense "
                       "KV has no sub-quadratic state; skipped per assignment "
                       "rule (see DESIGN.md §Shape-coverage)")


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """An assigned architecture: exact config + reduced smoke twin."""

    arch_id: str
    model: ModelConfig
    smoke: ModelConfig
    optimizer: str = "adamw"            # adamw | adafactor
    opt_state_dtype: str = "bfloat16"
    skip_shapes: Mapping[str, str] = dataclasses.field(default_factory=dict)
    source: str = ""

    def runs(self, shape_name: str) -> bool:
        return shape_name not in self.skip_shapes
