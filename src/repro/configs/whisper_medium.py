"""whisper-medium — encoder-decoder audio transformer [arXiv:2212.04356].

24L (decoder; + 24L encoder) d_model=1024 16H d_ff=4096 vocab=51865.
The conv frontend is a stub per the assignment: ``input_specs()`` provides
precomputed 1500-frame embeddings.  LayerNorm + GELU + learned positions,
per the original architecture.  ``max_position`` is widened to 32k so the
assigned prefill/decode shapes are well-defined.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ArchSpec
from repro.models.transformer import ModelConfig, uniform_pattern

MODEL = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, d_ff=4096,
    vocab_size=51865,
    patterns=uniform_pattern("attn_cross", 24),
    encoder_layers=24, cross_seq=1500,
    norm="layernorm", norm_eps=1e-5, activation="gelu", glu=False,
    use_rope=False, max_position=32_768,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=512,
    patterns=uniform_pattern("attn_cross", 2),
    encoder_layers=2, cross_seq=12,
    norm="layernorm", norm_eps=1e-5, activation="gelu", glu=False,
    use_rope=False, max_position=64,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="whisper-medium", model=MODEL, smoke=SMOKE,
    source="arXiv:2212.04356",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
)
