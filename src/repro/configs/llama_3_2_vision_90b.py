"""llama-3.2-vision-90b — VLM with cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision, scaled per assignment].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  Every 5th layer
is a gated cross-attention layer over precomputed vision-patch embeddings
(the vision tower is a stub per the assignment; ``input_specs()`` provides
[B, 6404, 8192] patch embeddings = 4 tiles × 1601 patches).
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ArchSpec
from repro.models.transformer import ModelConfig, Pattern, StageSpec

MODEL = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8, d_ff=28672,
    vocab_size=128256,
    patterns=(Pattern(20, (StageSpec("attn", 4, 0),
                           StageSpec("cross", 1, 0))),),
    cross_seq=6404,
    activation="silu", glu=True, rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512,
    patterns=(Pattern(1, (StageSpec("attn", 4, 0),
                          StageSpec("cross", 1, 0))),),
    cross_seq=12,
    activation="silu", glu=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="llama-3.2-vision-90b", model=MODEL, smoke=SMOKE,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
)
