"""gemma3-1b — dense with 5:1 local:global attention [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (MQA kv=1) d_ff=6912 vocab=262144, head_dim=256,
sliding window 512 on local layers, GeGLU, RMSNorm(1+w), 128k-class rope.
26 = 4 × (5 local + 1 global) + 2 local.

long_500k runs: the 22 local layers keep only a 512-slot ring; the 4 global
layers hold full KV, and decode cost is linear per token.
"""
from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig, Pattern, StageSpec

_WINDOW = 512

MODEL = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1, d_ff=6912,
    vocab_size=262144, head_dim=256,
    patterns=(
        Pattern(4, (StageSpec("attn", 5, _WINDOW), StageSpec("attn", 1, 0))),
        Pattern(1, (StageSpec("attn", 2, _WINDOW),)),
    ),
    activation="gelu", glu=True, norm_plus_one=True, embed_scale=True,
    tie_embeddings=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    num_layers=8, d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
    vocab_size=512, head_dim=32,
    patterns=(
        Pattern(2, (StageSpec("attn", 2, 16), StageSpec("attn", 1, 0))),
        Pattern(1, (StageSpec("attn", 2, 16),)),
    ),
    activation="gelu", glu=True, norm_plus_one=True, embed_scale=True,
    tie_embeddings=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="gemma3-1b", model=MODEL, smoke=SMOKE,
    source="hf:google/gemma-3-1b-pt",
)
