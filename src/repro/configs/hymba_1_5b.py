"""hymba-1.5b — hybrid parallel attention+Mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hymba runs attention and SSM heads in parallel inside each layer and fuses
the (per-branch normalised) outputs.  Most layers use sliding-window
attention; layers {0, 15, 31} are global (first/middle/last, per the paper).
"""
from repro.configs.base import ArchSpec
from repro.models.transformer import ModelConfig, Pattern, StageSpec

_WINDOW = 1024

MODEL = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5, d_ff=5504,
    vocab_size=32001,
    patterns=(Pattern(1, (
        StageSpec("hybrid", 1, 0),           # layer 0: global
        StageSpec("hybrid", 14, _WINDOW),    # layers 1..14: local
        StageSpec("hybrid", 1, 0),           # layer 15: global
        StageSpec("hybrid", 15, _WINDOW),    # layers 16..30: local
        StageSpec("hybrid", 1, 0),           # layer 31: global
    )),),
    ssm_state=16, d_inner=3200, dt_rank=100, conv_kernel=4,
    activation="silu", glu=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512,
    patterns=(Pattern(1, (
        StageSpec("hybrid", 1, 0),
        StageSpec("hybrid", 2, 16),
        StageSpec("hybrid", 1, 0),
    )),),
    ssm_state=8, d_inner=128, dt_rank=16, conv_kernel=4,
    activation="silu", glu=True, tie_embeddings=True,
    param_dtype="float32", capacity_factor=8.0,
)

ARCH = ArchSpec(
    arch_id="hymba-1.5b", model=MODEL, smoke=SMOKE,
    source="arXiv:2411.13676; hf",
    # sliding-window + SSM state => sub-quadratic; long_500k runs.
)
