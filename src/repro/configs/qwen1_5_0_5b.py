"""qwen1.5-0.5b — dense with QKV bias [hf:Qwen/Qwen1.5-0.5B].

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ArchSpec
from repro.models.transformer import ModelConfig, uniform_pattern

MODEL = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, d_ff=2816,
    vocab_size=151936,
    patterns=uniform_pattern("attn", 24),
    qkv_bias=True, tie_embeddings=True,
    activation="silu", glu=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=512,
    patterns=uniform_pattern("attn", 2),
    qkv_bias=True, tie_embeddings=True,
    activation="silu", glu=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="qwen1.5-0.5b", model=MODEL, smoke=SMOKE,
    source="hf:Qwen/Qwen1.5-0.5B",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
)
