"""Model-layer primitives shared by every assigned architecture.

Pure JAX (no flax).  Parameters are plain pytrees of jnp arrays; every layer is a
function ``(params, x, ...) -> y``.  Sharding is expressed two ways:

* GSPMD ``with_sharding_constraint`` hints on activations (no-ops off-mesh), and
* an explicit ``shard_map`` expert-parallel path for MoE (the only layer whose
  collective pattern GSPMD cannot be trusted to infer at 480B scale).

All attention variants route through :func:`attention_core` /
:func:`chunked_attention` so the 32k-prefill cells never materialise an
``[B, H, S, S]`` score tensor.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------

def shard(x: jax.Array, spec: Optional[P]) -> jax.Array:
    """Apply a sharding constraint if we are tracing under a mesh."""
    if spec is None:
        return x
    try:
        return lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        # Not under a mesh (unit tests / pure-CPU smoke) — constraint is a hint only.
        return x


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical→mesh axis mapping used by every layer.

    ``batch`` may span several mesh axes (("pod", "data")), ``tensor`` is the
    Megatron tensor-parallel axis, ``fsdp`` the parameter-sharding axis.  Any
    field may be None to disable that form of parallelism (single-host smoke).
    """

    batch: Any = None          # e.g. ("pod", "data") or "data"
    tensor: Any = None         # e.g. "model"
    fsdp: Any = None           # e.g. "data"
    # When the global batch is too small to occupy the batch axes (long_500k has
    # batch=1) the runner sets ``seq_shards`` so long sequence/state dims are
    # sharded over every axis instead.
    seq: Any = None            # axes for long sequence dims in decode
    # Sequence parallelism (train/prefill): residual-stream activations at
    # layer boundaries are sharded over this axis so the remat-saved stack is
    # 1/TP the size; GSPMD turns the row-parallel psum into a reduce-scatter
    # and inserts the SP all-gather at the next matmul.
    act_seq: Any = None
    # MoE weight handling: True gathers FSDP-sharded expert weights on use
    # (right for training, where every token batch reuses them); False keeps
    # weights 2-D sharded (E over tensor, D/F over fsdp) and gathers TOKENS
    # over the batch axes instead, psumming the tiny expert activations —
    # the decode regime, where weights are read once per token and the
    # per-step gather of multi-GB expert tensors is pure waste (§Perf).
    moe_gather_weights: bool = True
    # Sequence-parallel attention: keep Q (and the residual) seq-sharded
    # through the attention block and all-gather only the K/V heads —
    # n_kv·hd bytes instead of d_model per token.  Wins when
    # n_kv·hd ≪ d_model (GQA at large d_model: llama-90b gathers 8×128
    # instead of 8192 per token, ~8× less attention-path gather traffic);
    # the attention weights are gathered over the tensor axis instead
    # (≈MBs — amortised over the whole batch).
    seq_parallel_attn: bool = False

    def act(self, *rest) -> Optional[P]:
        """Spec for an activation whose leading dim is batch."""
        if self.batch is None and all(r is None for r in rest):
            return None
        return P(self.batch, *rest)

    def residual(self) -> Optional[P]:
        """Spec for the [B, S, D] residual stream at layer boundaries."""
        return self.act(self.act_seq, None)


NO_SHARD = ShardingRules()


# ---------------------------------------------------------------------------
# Basic blocks
# ---------------------------------------------------------------------------

def rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if plus_one:                      # gemma stores scale as (1 + w)
        w = 1.0 + w
    return (y * w).astype(x.dtype)


def layer_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def apply_norm(params, x, *, kind: str, eps: float, plus_one: bool = False):
    if kind == "layernorm":
        return layer_norm(params, x, eps)
    return rms_norm(params["scale"], x, eps, plus_one=plus_one)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [..., S, H, D_head]; positions: [S] or [B, S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq          # [..., S, half]
    # broadcast over head dim: [..., S, 1, half]
    ang = ang[..., None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name}")


def mlp(params: dict, x: jax.Array, *, activation: str, glu: bool,
        rules: ShardingRules = NO_SHARD) -> jax.Array:
    """(Gated) MLP.  Column-parallel up/gate, row-parallel down."""
    h = x @ params["up"]
    if glu:
        g = x @ params["gate"]
        h = _act(activation, g) * h
    else:
        h = _act(activation, h)
    h = shard(h, rules.act(None, rules.tensor))
    out = h @ params["down"]
    return shard(out, rules.residual())


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _qkv(params: dict, x: jax.Array, *, n_heads: int, n_kv: int, head_dim: int,
         qkv_bias: bool):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """[B, S, KV, D] -> [B, S, H, D] by repeating each kv head."""
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def attention_core(q, k, v, *, causal: bool, window: int = 0,
                   q_offset: int | jax.Array = 0,
                   kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Dense attention.  q: [B, Sq, H, D]; k, v: [B, Sk, H, D].

    ``q_offset`` is the absolute position of q[0] (decode: current pos).
    ``kv_valid`` optionally masks cache slots ([B, Sk] or [Sk]).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(Sq) + q_offset                     # [Sq]
    kpos = jnp.arange(Sk)                                # [Sk]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    mask = mask[None, None]
    if kv_valid is not None:
        kvm = kv_valid if kv_valid.ndim == 2 else kv_valid[None]
        mask = mask & kvm[:, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanning over KV chunks.

    Never materialises [B, H, Sq, Sk]; peak transient is [B, H, Sq, chunk].
    Used for the 32k-prefill cells; also the jnp oracle shape for a future
    Pallas flash kernel.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    if Sk <= chunk:
        return attention_core(q, k, v, causal=causal, window=window)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, H, D).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(D)
    qpos = jnp.arange(Sq)

    # flash-style: recompute chunk probabilities in the backward pass instead
    # of letting scan stack [n_chunks, B, H, Sq, chunk] f32 residuals.
    @jax.checkpoint
    def body(carry, xs):
        m, l, acc = carry                     # [B,H,Sq], [B,H,Sq], [B,H,Sq,D]
        ci, (kb, vb) = xs
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                            preferred_element_type=jnp.float32) * scale
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < Sk
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0),
                              (jnp.arange(n_chunks), (kc, vc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)      # [B, Sq, H, D]


def self_attention(params: dict, x: jax.Array, *, n_heads: int, n_kv: int,
                   head_dim: int, qkv_bias: bool, rope_theta: float,
                   causal: bool, window: int, positions: jax.Array,
                   use_rope: bool = True, chunk_threshold: int = 2048,
                   rules: ShardingRules = NO_SHARD) -> jax.Array:
    """Full-sequence self-attention (train / prefill path).

    Default sharding: q is head-sharded over the tensor axis; k/v are
    explicitly *replicated* over it (GQA kv-head counts rarely divide the
    16-way axis, and letting GSPMD split 2 kv heads over 16 devices
    triggers involuntary full rematerialisation — one small all-gather of
    k/v is far cheaper).

    ``rules.seq_parallel_attn``: q and the residual stay seq-sharded over
    the tensor axis and only K/V are gathered — n_kv·hd per token instead
    of d_model (8× less on llama-90b's GQA).
    """
    q, k, v = _qkv(params, x, n_heads=n_heads, n_kv=n_kv, head_dim=head_dim,
                   qkv_bias=qkv_bias)
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    sp = rules.seq_parallel_attn and rules.act_seq is not None
    if sp:
        q = shard(q, rules.act(rules.act_seq, None, None))
        k = shard(k, rules.act(None, None, None))
        v = shard(v, rules.act(None, None, None))
    else:
        q = shard(q, rules.act(None, rules.tensor, None))
        k = shard(k, rules.act(None, None, None))
        v = shard(v, rules.act(None, None, None))
    kf = _repeat_kv(k, n_heads)
    vf = _repeat_kv(v, n_heads)
    if x.shape[1] > chunk_threshold:
        o = chunked_attention(q, kf, vf, causal=causal, window=window)
    else:
        o = attention_core(q, kf, vf, causal=causal, window=window)
    o = o.reshape(x.shape[0], x.shape[1], n_heads * head_dim)
    o = shard(o, rules.act(rules.act_seq, None) if sp
              else rules.act(None, rules.tensor))
    out = o @ params["wo"]
    return shard(out, rules.residual())


def cross_attention(params: dict, x: jax.Array, kv_src: jax.Array | tuple,
                    *, n_heads: int, n_kv: int, head_dim: int, qkv_bias: bool,
                    rules: ShardingRules = NO_SHARD) -> jax.Array:
    """Cross-attention.  ``kv_src`` is either the encoder/patch sequence
    [B, Se, D] (keys projected here) or a precomputed (k, v) tuple (decode)."""
    B, Sq, _ = x.shape
    q = x @ params["wq"]
    if qkv_bias:
        q = q + params["bq"]
    q = q.reshape(B, Sq, n_heads, head_dim)
    if isinstance(kv_src, tuple):
        k, v = kv_src
    else:
        k, v = project_cross_kv(params, kv_src, n_kv=n_kv, head_dim=head_dim,
                                qkv_bias=qkv_bias)
    kf = _repeat_kv(k, n_heads)
    vf = _repeat_kv(v, n_heads)
    o = attention_core(q, kf, vf, causal=False)
    o = o.reshape(B, Sq, n_heads * head_dim)
    return shard(o @ params["wo"], rules.residual())


def project_cross_kv(params: dict, kv_src: jax.Array, *, n_kv: int,
                     head_dim: int, qkv_bias: bool):
    B, Se, _ = kv_src.shape
    k = kv_src @ params["wk"]
    v = kv_src @ params["wv"]
    if qkv_bias:
        k = k + params["bk"]
        v = v + params["bv"]
    return (k.reshape(B, Se, n_kv, head_dim), v.reshape(B, Se, n_kv, head_dim))


# ---------------------------------------------------------------------------
# Decode-path attention (KV cache, ring buffers for windows)
# ---------------------------------------------------------------------------

def decode_self_attention(params: dict, x: jax.Array, cache_k: jax.Array,
                          cache_v: jax.Array, pos: jax.Array, *, n_heads: int,
                          n_kv: int, head_dim: int, qkv_bias: bool,
                          rope_theta: float, window: int,
                          use_rope: bool = True,
                          rules: ShardingRules = NO_SHARD):
    """One-token decode.  x: [B, 1, D]; cache_k/v: [B, S_cache, KV, D_head].

    For windowed layers the cache is a ring buffer of size ``window``; for
    global layers S_cache is the full max context.  Returns (out, ck, cv).
    """
    B = x.shape[0]
    S_cache = cache_k.shape[1]
    q, k, v = _qkv(params, x, n_heads=n_heads, n_kv=n_kv, head_dim=head_dim,
                   qkv_bias=qkv_bias)
    if use_rope:
        posv = jnp.full((1,), pos)
        q = rope(q, posv, rope_theta)
        k = rope(k, posv, rope_theta)
    slot = jnp.where(window > 0, pos % S_cache, pos) if window else pos
    cache_k = lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                       (0, slot, 0, 0))
    cache_v = lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                       (0, slot, 0, 0))
    cache_k = shard(cache_k, rules.act(rules.seq, None, None))
    cache_v = shard(cache_v, rules.act(rules.seq, None, None))
    # validity: slot i holds position (for ring: the newest S_cache positions)
    idx = jnp.arange(S_cache)
    valid = idx <= pos if not window else (
        (idx <= pos) & (idx > pos - S_cache) | (pos >= S_cache))
    kf = _repeat_kv(cache_k.astype(q.dtype), n_heads)
    vf = _repeat_kv(cache_v.astype(q.dtype), n_heads)
    scale = 1.0 / math.sqrt(head_dim)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kf,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    o = o.reshape(B, 1, n_heads * head_dim)
    out = o @ params["wo"]
    return shard(out, rules.residual()), cache_k, cache_v


# ---------------------------------------------------------------------------
# Mixture of Experts (expert-parallel over the tensor axis via shard_map)
# ---------------------------------------------------------------------------

def moe_router(wg: jax.Array, x: jax.Array, top_k: int):
    """x: [T, D] -> (gates [T,k] fp32 normalised, idx [T,k] int32)."""
    logits = (x @ wg).astype(jnp.float32)
    gate_logits, idx = lax.top_k(logits, top_k)
    gates = jax.nn.softmax(gate_logits, axis=-1)
    return gates, idx


def _moe_local_compute(x, gates, idx, w_up, w_gate, w_down, *,
                       n_experts: int, top_k: int, capacity: int,
                       activation: str, e_start: int):
    """Dense grouped compute for the experts this shard owns.

    x: [T, D]; w_*: [E_loc, ...]; returns partial output [T, D] containing the
    contribution of experts [e_start, e_start + E_loc).
    """
    T, D = x.shape
    E_loc = w_up.shape[0]
    flat_e = idx.reshape(-1)                                  # [T*k]
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    local = (flat_e >= e_start) & (flat_e < e_start + E_loc)
    loc_e = jnp.where(local, flat_e - e_start, E_loc)         # E_loc = drop bin
    # position of each assignment within its expert, via sorted ranking
    order = jnp.argsort(loc_e, stable=True)                   # [T*k]
    sorted_e = loc_e[order]
    seg_first = jnp.searchsorted(sorted_e, jnp.arange(E_loc + 1))
    pos_sorted = jnp.arange(T * top_k) - seg_first[sorted_e]
    keep = (pos_sorted < capacity) & (sorted_e < E_loc)
    keep_f = keep.astype(x.dtype)                # multiply, never jnp.where:
    buf_slot = jnp.where(keep, sorted_e * capacity + pos_sorted,
                         E_loc * capacity)       # (a [T*k, D] bool broadcast
    tok_sorted = flat_t[order]                   #  would be saved for the
    gate_sorted = flat_g[order]                  #  backward of select)
    # scatter token rows into the expert buffer [E_loc*capacity + 1, D]
    x_buf = jnp.zeros((E_loc * capacity + 1, D), x.dtype)
    x_buf = x_buf.at[buf_slot].set(x[tok_sorted] * keep_f[:, None])
    xb = x_buf[:-1].reshape(E_loc, capacity, D)
    h = jnp.einsum("ecd,edf->ecf", xb, w_up)
    if w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", xb, w_gate)
        h = _act(activation, g) * h
    else:
        h = _act(activation, h)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)                 # [E_loc, C, D]
    y_flat = y.reshape(E_loc * capacity, D)
    y_tok = y_flat[jnp.minimum(buf_slot, E_loc * capacity - 1)] * \
        keep_f[:, None]
    out = jnp.zeros((T, D), x.dtype)
    out = out.at[tok_sorted].add(y_tok * gate_sorted[:, None].astype(x.dtype))
    return out


def _moe_local_compute_2d(xg, xg_d, gates, idx, w_up, w_gate, w_down, *,
                          fsdp_ax, n_experts: int, top_k: int,
                          capacity: int, activation: str, e_start: int):
    """2-D-sharded expert compute (decode): weights keep their (E × tensor,
    D/F × fsdp) sharding; the D-contraction partials of the up/gate
    projections are psummed over fsdp *before* the nonlinearity, and the
    down projection contracts this shard's F-slice (partial, psummed by the
    caller).  Collective payloads are expert activations — [E_loc, C, F] —
    not weights.

    xg: [T, D] gathered tokens (for dtype/shape only); xg_d: [T, D_loc]
    this shard's D-slice.  Returns partial output [T, D].
    """
    T = xg.shape[0]
    D = xg.shape[1]
    E_loc = w_up.shape[0]
    flat_e = idx.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    local = (flat_e >= e_start) & (flat_e < e_start + E_loc)
    loc_e = jnp.where(local, flat_e - e_start, E_loc)
    order = jnp.argsort(loc_e, stable=True)
    sorted_e = loc_e[order]
    seg_first = jnp.searchsorted(sorted_e, jnp.arange(E_loc + 1))
    pos_sorted = jnp.arange(T * top_k) - seg_first[sorted_e]
    keep = (pos_sorted < capacity) & (sorted_e < E_loc)
    keep_f = keep.astype(xg.dtype)
    buf_slot = jnp.where(keep, sorted_e * capacity + pos_sorted,
                         E_loc * capacity)
    tok_sorted = flat_t[order]
    gate_sorted = flat_g[order]

    xd_buf = jnp.zeros((E_loc * capacity + 1, xg_d.shape[1]), xg.dtype)
    xd_buf = xd_buf.at[buf_slot].set(xg_d[tok_sorted] * keep_f[:, None])
    xb = xd_buf[:-1].reshape(E_loc, capacity, xg_d.shape[1])

    h = jnp.einsum("ecd,edf->ecf", xb, w_up)          # partial over D
    if w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", xb, w_gate)
        h, g = lax.psum((h, g), fsdp_ax)              # tiny activations
        h = _act(activation, g) * h
    else:
        h = lax.psum(h, fsdp_ax)
        h = _act(activation, h)
    f_loc = w_down.shape[1]
    f0 = lax.axis_index(fsdp_ax) * f_loc
    h_f = lax.dynamic_slice_in_dim(h, f0, f_loc, axis=2)
    y = jnp.einsum("ecf,efd->ecd", h_f, w_down)       # partial over F
    y_flat = y.reshape(E_loc * capacity, D)
    y_tok = y_flat[jnp.minimum(buf_slot, E_loc * capacity - 1)] * \
        keep_f[:, None]
    out = jnp.zeros((T, D), xg.dtype)
    out = out.at[tok_sorted].add(y_tok * gate_sorted[:, None].astype(
        xg.dtype))
    return out


def moe_block(params: dict, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float, activation: str, glu: bool,
              mesh: Optional[jax.sharding.Mesh],
              rules: ShardingRules = NO_SHARD) -> jax.Array:
    """MoE FFN.  x: [B, S, D] (replicated over tensor axis, sharded over batch).

    Expert parallelism: experts sharded over the tensor axis; each shard
    routes every local token, computes its experts' contributions densely at
    fixed capacity, and psums partial outputs over the tensor axis.  Expert
    weights are additionally FSDP-sharded over the batch/fsdp axis and
    all-gathered on use.
    """
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    gates, idx = moe_router(params["router"], xf, top_k)

    if mesh is None or rules.tensor is None:
        T = B * S
        capacity = max(int(T * top_k * capacity_factor / n_experts), top_k)
        out = _moe_local_compute(
            xf, gates, idx, params["up"],
            params.get("gate") if glu else None, params["down"],
            n_experts=n_experts, top_k=top_k, capacity=capacity,
            activation=activation, e_start=0)
        return out.reshape(B, S, D)

    tensor_ax = rules.tensor
    fsdp_ax = rules.fsdp
    n_shards = mesh.shape[tensor_ax]
    batch_axes = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    batch_axes = tuple(a for a in batch_axes if a is not None)
    batch_size = max(math.prod(mesh.shape[a] for a in batch_axes), 1)
    T_loc = (B * S) // batch_size
    E_loc = n_experts // n_shards
    gather_w = rules.moe_gather_weights or fsdp_ax is None
    capacity = max(int((T_loc if gather_w else T_loc * batch_size)
                       * top_k * capacity_factor / n_experts), top_k)

    wspec = P(tensor_ax, fsdp_ax, None)
    tspec = P(batch_axes if batch_axes else None, None)

    # checkpoint: the dispatch gather/scatter chain would otherwise stack
    # O(T*k*D) broadcast residuals for its backward; recompute it instead
    # (this also re-gathers FSDP weights in the backward — ZeRO-3 semantics).
    @jax.checkpoint
    def local_fn(xf, gates, idx, *weights):
        if glu:
            w_up, w_gate, w_down = weights
        else:
            (w_up, w_down), w_gate = weights, None
        e_start = lax.axis_index(tensor_ax) * E_loc
        if gather_w:
            # training path: gather FSDP-sharded expert weights on use
            if fsdp_ax is not None:
                w_up = lax.all_gather(w_up, fsdp_ax, axis=1, tiled=True)
                w_down = lax.all_gather(w_down, fsdp_ax, axis=1, tiled=True)
                if w_gate is not None:
                    w_gate = lax.all_gather(w_gate, fsdp_ax, axis=1,
                                            tiled=True)
            out = _moe_local_compute(
                xf, gates, idx, w_up, w_gate, w_down,
                n_experts=n_experts, top_k=top_k, capacity=capacity,
                activation=activation, e_start=e_start)
            return lax.psum(out, tensor_ax)

        # decode path: weights stay 2-D sharded (E x tensor, D/F x fsdp);
        # gather the (tiny) token batch over the batch axes, contract
        # against the local D-shard of w_up / F-shard of w_down, and psum
        # the partial expert activations — a few MB of collectives instead
        # of multi-GB weight gathers.
        T_all = xf.shape[0] * batch_size
        xg = lax.all_gather(xf, batch_axes, axis=0, tiled=True)
        gg = lax.all_gather(gates, batch_axes, axis=0, tiled=True)
        ig = lax.all_gather(idx, batch_axes, axis=0, tiled=True)
        d_loc = w_up.shape[1]
        d0 = lax.axis_index(fsdp_ax) * d_loc
        xg_d = lax.dynamic_slice_in_dim(xg, d0, d_loc, axis=1)

        out = _moe_local_compute_2d(
            xg, xg_d, gg, ig, w_up, w_gate, w_down, fsdp_ax=fsdp_ax,
            n_experts=n_experts, top_k=top_k, capacity=capacity,
            activation=activation, e_start=e_start)
        # partial over the expert partition (tensor) and the D/F
        # contraction shards (fsdp); pod replicas computed identical work
        out = lax.psum(out, (tensor_ax, fsdp_ax))
        # slice this shard's tokens back out
        flat = jnp.zeros((), jnp.int32)
        for a in batch_axes:
            flat = flat * mesh.shape[a] + lax.axis_index(a)
        return lax.dynamic_slice_in_dim(out, flat * xf.shape[0],
                                        xf.shape[0], axis=0)

    weights = ((params["up"], params["gate"], params["down"]) if glu
               else (params["up"], params["down"]))
    in_specs = (tspec, tspec, tspec) + (wspec,) * len(weights)
    # Under sequence parallelism the residual stream arrives seq-sharded over
    # the tensor axis; every expert shard needs all of its tokens, so gather
    # tokens over the tensor axis here (the SP all-gather).
    xf = shard(xf, tspec)
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=tspec, check_vma=False)
    out = fn(xf, gates, idx, *weights)
    out = out.reshape(B, S, D)
    return shard(out, rules.residual())


# ---------------------------------------------------------------------------
# Mamba-1 selective SSM
# ---------------------------------------------------------------------------

def _pin(x, spec):
    return shard(x, spec) if spec is not None else x


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear_scan(a, b, h0, spec):
    """h[t] = a[t]⊙h[t-1] + b[t] along axis 1, h[-1] = h0.

    Custom VJP: the adjoint of a linear recurrence is the *reversed*
    recurrence g[t] = a[t+1]⊙g[t+1] + ḣ[t], so the backward pass is
    another associative scan with the same explicit sharding pins —
    autodiff through ``lax.associative_scan`` leaves GSPMD free to
    replicate the transposed scan's [B, c, d_inner, N] transients
    (measured: ~400 GB/step of full-d_inner all-gathers on hymba
    train_4k), which this eliminates.  ``spec`` pins every transient.
    """
    return _linear_scan_fwd(a, b, h0, spec)[0]


def _scan_core(a, b, spec):
    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, bb = lax.associative_scan(op, (a, b), axis=1)
    return _pin(aa, spec), _pin(bb, spec)


def _linear_scan_fwd(a, b, h0, spec):
    aa, bb = _scan_core(a, b, spec)
    h = _pin(aa * h0[:, None] + bb, spec)
    return h, (a, h, h0)


def _linear_scan_bwd(spec, res, gh):
    a, h, h0 = res
    gh = _pin(gh, spec)
    ones = jnp.ones_like(a[:, :1])
    a_next = _pin(jnp.concatenate([a[:, 1:], ones], axis=1), spec)
    ar = jnp.flip(a_next, axis=1)
    gr = jnp.flip(gh, axis=1)
    _, gg = _scan_core(ar, gr, spec)
    g = _pin(jnp.flip(gg, axis=1), spec)
    h_prev = _pin(jnp.concatenate([h0[:, None], h[:, :-1]], axis=1), spec)
    da = _pin(g * h_prev, spec)
    db = g
    dh0 = a[:, 0] * g[:, 0]
    return da, db, dh0


linear_scan.defvjp(_linear_scan_fwd, _linear_scan_bwd)

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds.  x: [B, S, C]; w: [K, C]."""
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[K - 1 - i]
    return out + b


def _ssm_params(params: dict, xc: jax.Array, *, d_state: int):
    """Input-dependent Δ, B, C.  xc: [B, S, d_inner]."""
    proj = xc @ params["x_proj"]                 # [B, S, dt_rank + 2N]
    dt_rank = params["dt_proj"].shape[0]
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"] + params["dt_bias"])  # [B,S,di]
    return dt, Bc, Cc


def selective_scan(xc, dt, Bc, Cc, A_log, D_skip, *, chunk: int = 512,
                   rules: ShardingRules = NO_SHARD):
    """Selective state-space scan (Mamba-1), chunked to bound transients.

    xc, dt: [B, S, di]; Bc, Cc: [B, S, N]; A_log: [di, N].
    Sequential scan over chunks, associative scan within a chunk; peak
    transient is [B, chunk, di, N].  Returns (y [B, S, di], h_last [B, di, N]).

    Sharding: d_inner is tensor-parallel, and the [B, c, di, N] transients
    MUST be pinned to that sharding — without the explicit constraints
    GSPMD replicates the associative scan's operands, all-gathering the
    full-d_inner f32 transients every layer (measured: +400 GB/step of
    gathers on hymba train_4k).  y is cast to the residual dtype *inside*
    the chunk body so the stacked scan output is a pure bf16
    dynamic-update-slice (in place), not an f32 buffer converted at the
    root (which XLA cannot update in place).
    """
    B, S, di = xc.shape
    N = Bc.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))                     # [di, N]
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc_p = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc_p = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p, dt_p, Bc_p, Cc_p = xc, dt, Bc, Cc

    chunk_spec = (P(rules.batch, None, rules.tensor, None)
                  if rules.tensor is not None else None)

    # checkpointed: the scan's VJP would otherwise stack every chunk's
    # [B, c, di, N] f32 intermediates (dA, dBx, assoc-scan levels) —
    # measured as the dominant HBM term on hymba/falcon train.  With the
    # checkpoint, backward re-derives them from the (bf16) chunk inputs
    # and the tiny [B, di, N] carry; linear_scan's custom VJP keeps the
    # reverse scan's transients pinned to the same sharding.
    @jax.checkpoint
    def chunk_body(h0, xs):
        xcb, dtb, Bcb, Ccb = xs                                 # [B, chunk, ...]
        dA = jnp.exp(dtb.astype(jnp.float32)[..., None] * A)    # [B,c,di,N]
        dBx = (dtb * xcb).astype(jnp.float32)[..., None] * \
            Bcb.astype(jnp.float32)[..., None, :]               # [B,c,di,N]
        dA = _pin(dA, chunk_spec)
        dBx = _pin(dBx, chunk_spec)
        h = linear_scan(dA, dBx, h0, chunk_spec)                # [B,c,di,N]
        y = jnp.einsum("bcdn,bcn->bcd", h, Ccb.astype(jnp.float32))
        h_last = h[:, -1]
        return h_last, y.astype(xc.dtype)

    h0 = jnp.zeros((B, di, N), jnp.float32)
    xs = tuple(t.reshape(B, n_chunks, chunk, -1).swapaxes(0, 1)
               for t in (xc_p, dt_p, Bc_p, Cc_p))
    h_last, ys = lax.scan(chunk_body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, n_chunks * chunk, di)[:, :S]
    return (y + (xc * D_skip).astype(xc.dtype)), h_last


def mamba_mixer(params: dict, x: jax.Array, *, d_state: int,
                rules: ShardingRules = NO_SHARD) -> jax.Array:
    """Full-sequence Mamba-1 mixer.  x: [B, S, D] -> [B, S, D]."""
    xz = x @ params["in_proj"]                                  # [B,S,2*di]
    xz = shard(xz, rules.act(None, rules.tensor))
    xc, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xc, params["conv_w"], params["conv_b"]))
    dt, Bc, Cc = _ssm_params(params, xc, d_state=d_state)
    y, _ = selective_scan(xc, dt, Bc, Cc, params["A_log"], params["D"],
                          rules=rules)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return shard(out, rules.residual())


def mamba_decode(params: dict, x: jax.Array, conv_state: jax.Array,
                 ssm_state: jax.Array, *, d_state: int,
                 rules: ShardingRules = NO_SHARD):
    """Single-token Mamba step.

    x: [B, 1, D]; conv_state: [B, K-1, di]; ssm_state: [B, di, N] fp32.
    Returns (out [B,1,D], conv_state, ssm_state).
    """
    B = x.shape[0]
    xz = x[:, 0] @ params["in_proj"]                            # [B, 2*di]
    xc, z = jnp.split(xz, 2, axis=-1)
    w = params["conv_w"]                                        # [K, di]
    K = w.shape[0]
    hist = jnp.concatenate([conv_state, xc[:, None]], axis=1)   # [B, K, di]
    conv = jnp.einsum("bkd,kd->bd", hist, w) + params["conv_b"]
    new_conv_state = hist[:, 1:]
    xc = jax.nn.silu(conv)
    proj = xc @ params["x_proj"]
    dt_rank = params["dt_proj"].shape[0]
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"] + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)         # [B, di, N]
    dBx = (dt * xc).astype(jnp.float32)[..., None] * \
        Bc.astype(jnp.float32)[:, None, :]
    h = dA * ssm_state + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32))
    y = (y + xc.astype(jnp.float32) * params["D"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None]
    return shard(out, rules.residual()), new_conv_state, h


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed(table: jax.Array, tokens: jax.Array, *, scale: bool) -> jax.Array:
    x = table[tokens]
    if scale:
        x = x * math.sqrt(table.shape[1])
    return x.astype(table.dtype)


def lm_logits(params: dict, x: jax.Array, *, tied: bool) -> jax.Array:
    w = params["embed"].T if tied else params["lm_head"]
    return (x @ w).astype(jnp.float32)
