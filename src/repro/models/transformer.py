"""Unified transformer/SSM model covering every assigned architecture.

A model is a sequence of :class:`Pattern` groups; each pattern is ``repeats``
copies of a heterogeneous stage list (e.g. gemma3 = 4×[5 local, 1 global] +
[2 local]).  Both the repeat dimension and each stage's layer dimension are
``lax.scan``-ed, so the compiled HLO contains one body per *stage kind*, not
per layer — essential for compiling 100-layer models on the 512-device
dry-run mesh in reasonable time.

Parameters, sharding specs, and decode caches are all produced by one
structure builder (`_build_params`) so they can never drift apart.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.layers import NO_SHARD, ShardingRules, shard


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageSpec:
    kind: str                 # attn | attn_cross | cross | mamba | hybrid | enc
    count: int
    window: int = 0           # 0 = global attention


@dataclasses.dataclass(frozen=True)
class Pattern:
    repeats: int
    stages: tuple[StageSpec, ...]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    patterns: tuple[Pattern, ...]
    head_dim: int = 0         # 0 -> d_model // num_heads
    qkv_bias: bool = False
    activation: str = "silu"
    glu: bool = True
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    norm_plus_one: bool = False
    embed_scale: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    max_position: int = 0     # >0 -> learned positions (whisper)
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_dense_residual: bool = False
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    d_inner: int = 0
    dt_rank: int = 0
    conv_kernel: int = 4
    # cross-attention source (encoder frames / vision patches)
    cross_seq: int = 0
    encoder_layers: int = 0
    param_dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def validate(self) -> None:
        n = sum(p.repeats * sum(s.count for s in p.stages)
                for p in self.patterns)
        assert n == self.num_layers, (
            f"{self.name}: pattern layers {n} != num_layers {self.num_layers}")


def uniform_pattern(kind: str, n_layers: int, window: int = 0) -> tuple[Pattern, ...]:
    return (Pattern(1, (StageSpec(kind, n_layers, window),)),)


# ---------------------------------------------------------------------------
# Parameter construction (arrays, sharding specs, and counts from one builder)
# ---------------------------------------------------------------------------

# Mesh-axis sizes assumed by parameter PartitionSpecs.  pjit in_shardings
# require exact divisibility (unlike activation constraints, which pad), so
# the spec builder drops any axis that does not divide the dimension —
# e.g. whisper's 51865-row embedding stays replicated.
MESH_AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


class _Maker:
    """Builds leaves: either initialised arrays or PartitionSpecs."""

    def __init__(self, cfg: ModelConfig, mode: str, key=None,
                 stack: tuple[int, ...] = ()):
        self.cfg, self.mode, self.key, self.stack = cfg, mode, key, stack

    def with_stack(self, *dims: int) -> "_Maker":
        return _Maker(self.cfg, self.mode, self.key, tuple(dims))

    def _fit_spec(self, shape, spec):
        out = []
        for dim, ax in zip(shape, spec):
            axes = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
            size = math.prod(MESH_AXIS_SIZES.get(a, 1) for a in axes)
            out.append(ax if size and dim % size == 0 else None)
        return tuple(out)

    def __call__(self, name: str, shape: tuple[int, ...], spec: tuple,
                 scale: float | None = None, dtype=None):
        full_shape = self.stack + tuple(shape)
        if self.mode == "spec":
            spec = self._fit_spec(shape, spec)
            return P(*((None,) * len(self.stack) + tuple(spec)))
        dtype = dtype or self.cfg.dtype
        if self.mode == "shape":
            return jax.ShapeDtypeStruct(full_shape, dtype)
        k = jax.random.fold_in(self.key, hash(name) % (2 ** 31))
        if scale == 0.0:
            return jnp.zeros(full_shape, dtype)
        if scale is None:
            scale = 1.0 / math.sqrt(shape[0] if len(shape) > 1 else 1)
        return (jax.random.normal(k, full_shape, jnp.float32) * scale
                ).astype(dtype)


def _norm_params(mk: _Maker, name: str, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return {"scale": mk(f"{name}.s", (cfg.d_model,), (None,), 0.0) + 1.0
                if mk.mode == "init" else mk(f"{name}.s", (cfg.d_model,), (None,)),
                "bias": mk(f"{name}.b", (cfg.d_model,), (None,), 0.0)}
    init = 0.0 if cfg.norm_plus_one else None
    s = mk(f"{name}.s", (cfg.d_model,), (None,), init)
    if mk.mode == "init" and not cfg.norm_plus_one:
        s = jnp.ones_like(s)
    return {"scale": s}


def _attn_params(mk: _Maker, name: str, cfg: ModelConfig,
                 cross: bool = False):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    t, f = "model", "data"
    p = {
        "wq": mk(f"{name}.wq", (D, H * hd), (f, t)),
        "wk": mk(f"{name}.wk", (D, KV * hd), (f, t)),
        "wv": mk(f"{name}.wv", (D, KV * hd), (f, t)),
        "wo": mk(f"{name}.wo", (H * hd, D), (t, f)),
    }
    if cfg.qkv_bias:
        p["bq"] = mk(f"{name}.bq", (H * hd,), (t,), 0.0)
        p["bk"] = mk(f"{name}.bk", (KV * hd,), (t,), 0.0)
        p["bv"] = mk(f"{name}.bv", (KV * hd,), (t,), 0.0)
    return p


def _mlp_params(mk: _Maker, name: str, cfg: ModelConfig, d_ff: int | None = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    p = {"up": mk(f"{name}.up", (D, F), ("data", "model")),
         "down": mk(f"{name}.down", (F, D), ("model", "data"))}
    if cfg.glu:
        p["gate"] = mk(f"{name}.gate", (D, F), ("data", "model"))
    return p


def _moe_params(mk: _Maker, name: str, cfg: ModelConfig):
    D, E, F = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    p = {"router": mk(f"{name}.router", (D, E), (None, None)),
         "up": mk(f"{name}.moe_up", (E, D, F), ("model", "data", None)),
         "down": mk(f"{name}.moe_down", (E, F, D), ("model", "data", None))}
    if cfg.glu:
        p["gate"] = mk(f"{name}.moe_gate", (E, D, F), ("model", "data", None))
    return p


def _mamba_params(mk: _Maker, name: str, cfg: ModelConfig):
    D, di, N, R, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                      cfg.conv_kernel)
    p = {
        "in_proj": mk(f"{name}.in", (D, 2 * di), ("data", "model")),
        "conv_w": mk(f"{name}.convw", (K, di), (None, "model")),
        "conv_b": mk(f"{name}.convb", (di,), ("model",), 0.0),
        "x_proj": mk(f"{name}.xproj", (di, R + 2 * N), ("model", None)),
        "dt_proj": mk(f"{name}.dtproj", (R, di), (None, "model")),
        "dt_bias": mk(f"{name}.dtbias", (di,), ("model",), 0.0),
        "A_log": mk(f"{name}.alog", (di, N), ("model", None), 0.0),
        "D": mk(f"{name}.dskip", (di,), ("model",), 0.0),
        "out_proj": mk(f"{name}.out", (di, D), ("model", "data")),
    }
    if mk.mode == "init":
        # A = -exp(A_log) must be negative & spread: A_log = log(1..N)
        base = jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))
        p["A_log"] = jnp.broadcast_to(base, p["A_log"].shape).astype(
            jnp.float32)
        p["D"] = jnp.ones_like(p["D"], jnp.float32)
        p["dt_bias"] = jnp.full_like(p["dt_bias"], -4.0, jnp.float32)
    return p


def _ffn_params(mk: _Maker, name: str, cfg: ModelConfig):
    """The per-layer FFN: dense MLP, MoE, or MoE + dense residual (arctic)."""
    if cfg.moe_experts:
        p = {"moe": _moe_params(mk, name, cfg)}
        if cfg.moe_dense_residual:
            p["mlp"] = _mlp_params(mk, name + ".res", cfg)
        return p
    return {"mlp": _mlp_params(mk, name, cfg)}


def _layer_params(mk: _Maker, name: str, cfg: ModelConfig, kind: str):
    p: dict[str, Any] = {"ln1": _norm_params(mk, f"{name}.ln1", cfg)}
    if kind in ("attn", "enc"):
        p["attn"] = _attn_params(mk, f"{name}.attn", cfg)
        p["ln2"] = _norm_params(mk, f"{name}.ln2", cfg)
        p.update(_ffn_params(mk, f"{name}.ffn", cfg))
    elif kind == "attn_cross":
        p["attn"] = _attn_params(mk, f"{name}.attn", cfg)
        p["lnx"] = _norm_params(mk, f"{name}.lnx", cfg)
        p["xattn"] = _attn_params(mk, f"{name}.xattn", cfg, cross=True)
        p["ln2"] = _norm_params(mk, f"{name}.ln2", cfg)
        p.update(_ffn_params(mk, f"{name}.ffn", cfg))
    elif kind == "cross":
        p["xattn"] = _attn_params(mk, f"{name}.xattn", cfg, cross=True)
        p["gate_attn"] = mk(f"{name}.ga", (), (), 0.0, dtype=jnp.float32)
        p["gate_mlp"] = mk(f"{name}.gm", (), (), 0.0, dtype=jnp.float32)
        p["ln2"] = _norm_params(mk, f"{name}.ln2", cfg)
        p.update(_ffn_params(mk, f"{name}.ffn", cfg))
    elif kind == "mamba":
        p["mixer"] = _mamba_params(mk, f"{name}.mixer", cfg)
    elif kind == "hybrid":
        p["attn"] = _attn_params(mk, f"{name}.attn", cfg)
        p["mixer"] = _mamba_params(mk, f"{name}.mixer", cfg)
        p["attn_norm"] = mk(f"{name}.an", (cfg.d_model,), (None,))
        p["ssm_norm"] = mk(f"{name}.sn", (cfg.d_model,), (None,))
        if mk.mode == "init":
            p["attn_norm"] = jnp.ones_like(p["attn_norm"])
            p["ssm_norm"] = jnp.ones_like(p["ssm_norm"])
        p["ln2"] = _norm_params(mk, f"{name}.ln2", cfg)
        p.update(_ffn_params(mk, f"{name}.ffn", cfg))
    else:
        raise ValueError(f"unknown layer kind {kind}")
    return p


def _build_params(cfg: ModelConfig, mode: str, key=None):
    mk = _Maker(cfg, mode, key)
    params: dict[str, Any] = {
        "embed": mk("embed", (cfg.vocab_size, cfg.d_model), ("model", None),
                    scale=1.0),
        "final_norm": _norm_params(mk, "final_norm", cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = mk("lm_head", (cfg.d_model, cfg.vocab_size),
                               ("data", "model"))
    if cfg.max_position:
        params["pos_embed"] = mk("pos_embed",
                                 (cfg.max_position, cfg.d_model),
                                 (None, None), scale=0.02)
    blocks = []
    for pi, pat in enumerate(cfg.patterns):
        stages = []
        for si, st in enumerate(pat.stages):
            smk = mk.with_stack(pat.repeats, st.count)
            stages.append(_layer_params(smk, f"p{pi}.s{si}", cfg, st.kind))
        blocks.append(stages)
    params["blocks"] = blocks
    if cfg.encoder_layers:
        enc_stage = mk.with_stack(1, cfg.encoder_layers)
        params["encoder"] = {
            "pos_embed": mk("enc.pos", (cfg.cross_seq, cfg.d_model),
                            (None, None), scale=0.02),
            "blocks": [[_layer_params(enc_stage, "enc.s0", cfg, "enc")]],
            "final_norm": _norm_params(mk, "enc.final_norm", cfg),
        }
    return params


def init_params(cfg: ModelConfig, key: jax.Array):
    return _build_params(cfg, "init", key)


def param_shapes(cfg: ModelConfig):
    return _build_params(cfg, "shape")


def param_specs(cfg: ModelConfig):
    return _build_params(cfg, "spec")


def param_count(cfg: ModelConfig) -> int:
    return sum(math.prod(l.shape) for l in
               jax.tree.leaves(param_shapes(cfg)))


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k of moe_experts)."""
    total = param_count(cfg)
    if not cfg.moe_experts:
        return total
    expert_leaves = 0
    shapes = param_shapes(cfg)
    for blockset in shapes["blocks"]:
        for stage in blockset:
            moe = stage.get("moe")
            if moe:
                for nm in ("up", "down", "gate"):
                    if nm in moe:
                        expert_leaves += math.prod(moe[nm].shape)
    active_experts = expert_leaves * cfg.moe_top_k / cfg.moe_experts
    return int(total - expert_leaves + active_experts)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _ffn_apply(lp: dict, h: jax.Array, cfg: ModelConfig, rules, mesh):
    if cfg.moe_experts:
        out = L.moe_block(lp["moe"], h, n_experts=cfg.moe_experts,
                          top_k=cfg.moe_top_k,
                          capacity_factor=cfg.capacity_factor,
                          activation=cfg.activation, glu=cfg.glu,
                          mesh=mesh, rules=rules)
        if cfg.moe_dense_residual:
            out = out + L.mlp(lp["mlp"], h, activation=cfg.activation,
                              glu=cfg.glu, rules=rules)
        return out
    return L.mlp(lp["mlp"], h, activation=cfg.activation, glu=cfg.glu,
                 rules=rules)


def _norm(lp, x, cfg):
    return L.apply_norm(lp, x, kind=cfg.norm, eps=cfg.norm_eps,
                        plus_one=cfg.norm_plus_one)


def _gnorm(lp, x, cfg, rules):
    """Norm + explicit gather over the SP axis, pinned at bf16.

    Two constraints, not one: pinning the norm output *seq-sharded first*
    and replicated second forces the SP all-gather to act on the bf16
    value between the two pins.  With only the final (replicated) pin,
    GSPMD propagates "replicated" backwards through the convert and
    all-gathers the f32 intermediate inside the norm — measured 2× wire
    bytes on every layer of llama-90b (§Perf).
    """
    h = _norm(lp, x, cfg)
    h = shard(h, rules.act(rules.act_seq, None))
    return shard(h, rules.act(None, None))


def _layer_fwd(cfg: ModelConfig, spec: StageSpec, lp, x, *, positions,
               cross_src, rules, mesh):
    kind = spec.kind
    if kind in ("attn", "enc", "attn_cross"):
        if rules.seq_parallel_attn and rules.act_seq is not None:
            # seq-parallel attention: the norm output stays seq-sharded
            h = shard(_norm(lp["ln1"], x, cfg), rules.residual())
        else:
            h = _gnorm(lp["ln1"], x, cfg, rules)
        a = L.self_attention(lp["attn"], h, n_heads=cfg.num_heads,
                             n_kv=cfg.num_kv_heads, head_dim=cfg.hd,
                             qkv_bias=cfg.qkv_bias, rope_theta=cfg.rope_theta,
                             causal=(kind != "enc"), window=spec.window,
                             positions=positions,
                             use_rope=cfg.use_rope and kind != "enc",
                             rules=rules)
        x = x + a
        if kind == "attn_cross":
            h = _gnorm(lp["lnx"], x, cfg, rules)
            c = L.cross_attention(lp["xattn"], h, cross_src,
                                  n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                                  head_dim=cfg.hd, qkv_bias=cfg.qkv_bias,
                                  rules=rules)
            x = x + c
        h = _gnorm(lp["ln2"], x, cfg, rules)
        x = x + _ffn_apply(lp, h, cfg, rules, mesh)
    elif kind == "cross":
        h = _gnorm(lp["ln1"], x, cfg, rules)
        c = L.cross_attention(lp["xattn"], h, cross_src,
                              n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                              head_dim=cfg.hd, qkv_bias=cfg.qkv_bias,
                              rules=rules)
        x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * c
        h = _gnorm(lp["ln2"], x, cfg, rules)
        x = x + jnp.tanh(lp["gate_mlp"]).astype(x.dtype) * \
            _ffn_apply(lp, h, cfg, rules, mesh)
    elif kind == "mamba":
        h = _gnorm(lp["ln1"], x, cfg, rules)
        x = x + L.mamba_mixer(lp["mixer"], h, d_state=cfg.ssm_state,
                              rules=rules)
    elif kind == "hybrid":
        h = _gnorm(lp["ln1"], x, cfg, rules)
        a = L.self_attention(lp["attn"], h, n_heads=cfg.num_heads,
                             n_kv=cfg.num_kv_heads, head_dim=cfg.hd,
                             qkv_bias=cfg.qkv_bias, rope_theta=cfg.rope_theta,
                             causal=True, window=spec.window,
                             positions=positions, use_rope=cfg.use_rope,
                             rules=rules)
        m = L.mamba_mixer(lp["mixer"], h, d_state=cfg.ssm_state, rules=rules)
        fused = 0.5 * (L.rms_norm(lp["attn_norm"], a, cfg.norm_eps) +
                       L.rms_norm(lp["ssm_norm"], m, cfg.norm_eps))
        x = x + fused
        h = _gnorm(lp["ln2"], x, cfg, rules)
        x = x + _ffn_apply(lp, h, cfg, rules, mesh)
    else:
        raise ValueError(kind)
    return shard(x, rules.residual())


def _run_patterns(cfg: ModelConfig, patterns, blocks, x, *, positions,
                  cross_src, rules, mesh, remat: bool = True):
    for pi, pat in enumerate(patterns):
        stage_params = tuple(blocks[pi])

        def repeat_body(x, xs, _pat=pat):
            for j, spec in enumerate(_pat.stages):
                fn = functools.partial(_layer_fwd, cfg, spec,
                                       positions=positions,
                                       cross_src=cross_src, rules=rules,
                                       mesh=mesh)
                if remat:
                    fn = jax.checkpoint(
                        lambda lp, h, _fn=fn: _fn(lp, h),
                        policy=jax.checkpoint_policies.nothing_saveable)

                def scan_body(h, lp, _fn=fn):
                    return _fn(lp, h), None
                x, _ = lax.scan(scan_body, x, xs[j])
            return x, None

        x, _ = lax.scan(repeat_body, x, stage_params)
    return x


def encode(cfg: ModelConfig, params, frames: jax.Array, *, rules=NO_SHARD,
           mesh=None) -> jax.Array:
    """Whisper-style encoder over precomputed conv-frontend frames."""
    enc = params["encoder"]
    x = frames.astype(cfg.dtype) + enc["pos_embed"][None, :frames.shape[1]]
    x = shard(x, rules.residual())
    pos = jnp.arange(frames.shape[1])
    enc_patterns = (Pattern(1, (StageSpec("enc", cfg.encoder_layers, 0),)),)
    x = _run_patterns(cfg, enc_patterns, enc["blocks"], x, positions=pos,
                      cross_src=None, rules=rules, mesh=mesh)
    return _norm(enc["final_norm"], x, cfg)


def forward(cfg: ModelConfig, params, tokens: jax.Array, *,
            cross_src: Optional[jax.Array] = None, rules=NO_SHARD,
            mesh=None, remat: bool = True) -> jax.Array:
    """Full-sequence forward -> final hidden states [B, S, D]."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, scale=cfg.embed_scale)
    if cfg.max_position:
        x = x + params["pos_embed"][None, :S]
    x = shard(x, rules.residual())
    positions = jnp.arange(S)
    if cfg.encoder_layers and cross_src is not None:
        cross_src = encode(cfg, params, cross_src, rules=rules, mesh=mesh)
    x = _run_patterns(cfg, cfg.patterns, params["blocks"], x,
                      positions=positions, cross_src=cross_src, rules=rules,
                      mesh=mesh, remat=remat)
    return _norm(params["final_norm"], x, cfg)


def logits_from_hidden(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    return L.lm_logits(params, x, tied=cfg.tie_embeddings)


def lm_loss(cfg: ModelConfig, params, tokens: jax.Array, *,
            cross_src=None, rules=NO_SHARD, mesh=None,
            loss_chunk: int = 1024) -> jax.Array:
    """Next-token CE, computed in sequence chunks so [B,S,V] fp32 logits are
    never fully materialised (matters for 262k vocabs at 4k×256 tokens)."""
    hidden = forward(cfg, params, tokens, cross_src=cross_src, rules=rules,
                     mesh=mesh)
    h = hidden[:, :-1]
    targets = tokens[:, 1:]
    B, S, D = h.shape
    n_chunks = -(-S // loss_chunk)
    pad = n_chunks * loss_chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    hc = h.reshape(B, n_chunks, loss_chunk, D).swapaxes(0, 1)
    tc = targets.reshape(B, n_chunks, loss_chunk).swapaxes(0, 1)
    valid = (jnp.arange(n_chunks * loss_chunk) < S).reshape(
        n_chunks, loss_chunk)

    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    @jax.checkpoint
    def chunk_loss(hb, tb, vb):
        logits = (hb @ w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # vocab-parallel target logit: one-hot contraction partitions cleanly
        # over a vocab-sharded logits tensor (take_along_axis would force an
        # all-gather of the full [B, chunk, V] logits).
        onehot = jax.nn.one_hot(tb, logits.shape[-1], dtype=logits.dtype)
        tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
        return jnp.sum((lse - tgt) * vb[None])

    def body(acc, xs):
        hb, tb, vb = xs
        return acc + chunk_loss(hb, tb, vb), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc, valid))
    return total / (B * S)


# ---------------------------------------------------------------------------
# Decode path (KV caches / SSM state)
# ---------------------------------------------------------------------------

def _cache_stage(cfg: ModelConfig, spec: StageSpec, mk: Callable, *,
                 batch: int, max_seq: int, rules: ShardingRules):
    """Cache arrays for one stage; mk(name, shape, spec_tuple, dtype)."""
    KV, hd = cfg.num_kv_heads, cfg.hd
    slen = min(spec.window, max_seq) if spec.window else max_seq
    seq_ax = rules.seq
    c: dict[str, Any] = {}
    if spec.kind in ("attn", "attn_cross", "hybrid"):
        kv_spec = (rules.batch, seq_ax, None, None)
        c["k"] = mk("k", (batch, slen, KV, hd), kv_spec, cfg.dtype)
        c["v"] = mk("v", (batch, slen, KV, hd), kv_spec, cfg.dtype)
    if spec.kind in ("attn_cross", "cross"):
        xk_spec = (rules.batch, None, None, None)
        c["xk"] = mk("xk", (batch, cfg.cross_seq, KV, hd), xk_spec, cfg.dtype)
        c["xv"] = mk("xv", (batch, cfg.cross_seq, KV, hd), xk_spec, cfg.dtype)
    if spec.kind in ("mamba", "hybrid"):
        di = cfg.d_inner
        c["conv"] = mk("conv", (batch, cfg.conv_kernel - 1, di),
                       (rules.batch, None, "model"), cfg.dtype)
        c["ssm"] = mk("ssm", (batch, di, cfg.ssm_state),
                      (rules.batch, "model", None), jnp.float32)
    return c


def _build_cache(cfg: ModelConfig, mode: str, *, batch: int, max_seq: int,
                 rules: ShardingRules):
    def make(stack):
        def mk(name, shape, spec, dtype):
            full = stack + tuple(shape)
            if mode == "spec":
                return P(*((None,) * len(stack) + tuple(spec)))
            return jax.ShapeDtypeStruct(full, dtype) if mode == "shape" \
                else jnp.zeros(full, dtype)
        return mk

    cache = []
    for pat in cfg.patterns:
        stages = []
        for st in pat.stages:
            stages.append(_cache_stage(cfg, st, make((pat.repeats, st.count)),
                                       batch=batch, max_seq=max_seq,
                                       rules=rules))
        cache.append(stages)
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               rules: ShardingRules = NO_SHARD):
    return _build_cache(cfg, "init", batch=batch, max_seq=max_seq,
                        rules=rules)


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                 rules: ShardingRules = NO_SHARD):
    return _build_cache(cfg, "shape", batch=batch, max_seq=max_seq,
                        rules=rules)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                rules: ShardingRules):
    return _build_cache(cfg, "spec", batch=batch, max_seq=max_seq,
                        rules=rules)


def _layer_decode(cfg: ModelConfig, spec: StageSpec, lp, cache, x, *,
                  pos, rules, mesh):
    kind = spec.kind
    new_cache = dict(cache)
    if kind in ("attn", "attn_cross", "hybrid"):
        h = _gnorm(lp["ln1"], x, cfg, rules)
        a, ck, cv = L.decode_self_attention(
            lp["attn"], h, cache["k"], cache["v"], pos,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.hd,
            qkv_bias=cfg.qkv_bias, rope_theta=cfg.rope_theta,
            window=spec.window, use_rope=cfg.use_rope, rules=rules)
        new_cache["k"], new_cache["v"] = ck, cv
        if kind == "hybrid":
            m, cc, cs = L.mamba_decode(lp["mixer"], h, cache["conv"],
                                       cache["ssm"], d_state=cfg.ssm_state,
                                       rules=rules)
            new_cache["conv"], new_cache["ssm"] = cc, cs
            fused = 0.5 * (L.rms_norm(lp["attn_norm"], a, cfg.norm_eps) +
                           L.rms_norm(lp["ssm_norm"], m, cfg.norm_eps))
            x = x + fused
        else:
            x = x + a
        if kind == "attn_cross":
            h = _gnorm(lp["lnx"], x, cfg, rules)
            c = L.cross_attention(lp["xattn"], h, (cache["xk"], cache["xv"]),
                                  n_heads=cfg.num_heads,
                                  n_kv=cfg.num_kv_heads, head_dim=cfg.hd,
                                  qkv_bias=cfg.qkv_bias, rules=rules)
            x = x + c
        h = _gnorm(lp["ln2"], x, cfg, rules)
        x = x + _ffn_apply(lp, h, cfg, rules, mesh)
    elif kind == "cross":
        h = _gnorm(lp["ln1"], x, cfg, rules)
        c = L.cross_attention(lp["xattn"], h, (cache["xk"], cache["xv"]),
                              n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                              head_dim=cfg.hd, qkv_bias=cfg.qkv_bias,
                              rules=rules)
        x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * c
        h = _gnorm(lp["ln2"], x, cfg, rules)
        x = x + jnp.tanh(lp["gate_mlp"]).astype(x.dtype) * \
            _ffn_apply(lp, h, cfg, rules, mesh)
    elif kind == "mamba":
        h = _gnorm(lp["ln1"], x, cfg, rules)
        m, cc, cs = L.mamba_decode(lp["mixer"], h, cache["conv"],
                                   cache["ssm"], d_state=cfg.ssm_state,
                                   rules=rules)
        new_cache["conv"], new_cache["ssm"] = cc, cs
        x = x + m
    else:
        raise ValueError(kind)
    return shard(x, rules.residual()), new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens: jax.Array,
                pos: jax.Array, *, rules=NO_SHARD, mesh=None):
    """One-token decode.  tokens: [B, 1]; pos: scalar int32 (aligned batch).

    Returns (logits [B, V] fp32, new_cache).
    """
    x = L.embed(params["embed"], tokens, scale=cfg.embed_scale)
    if cfg.max_position:
        x = x + lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1)[None]
    x = shard(x, rules.residual())
    new_cache = []
    for pi, pat in enumerate(cfg.patterns):
        stage_params = tuple(params["blocks"][pi])
        stage_caches = tuple(cache[pi])

        def repeat_body(x, xs, _pat=pat):
            lps, cjs = xs
            outs = []
            for j, spec in enumerate(_pat.stages):
                def scan_body(h, xs2, _spec=spec):
                    lp, cj = xs2
                    return _layer_decode(cfg, _spec, lp, cj, h, pos=pos,
                                         rules=rules, mesh=mesh)
                x, cj_new = lax.scan(scan_body, x, (lps[j], cjs[j]))
                outs.append(cj_new)
            return x, tuple(outs)

        x, pat_caches = lax.scan(repeat_body, x,
                                 (stage_params, stage_caches))
        new_cache.append(list(pat_caches))
    x = _norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(cfg, params, x)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill: full forward that also fills the decode caches
# ---------------------------------------------------------------------------

def prefill_step(cfg: ModelConfig, params, tokens: jax.Array, *,
                 max_seq: int | None = None, cross_src=None, rules=NO_SHARD,
                 mesh=None):
    """Forward over the prompt; returns (last-token logits, filled cache).

    ``max_seq`` sizes the cache (>= prompt length); defaults to the prompt
    length for the pure-prefill dry-run cells.  Windowed layers fill their
    ring buffers at ring-consistent slots (slot = position % window) so a
    subsequent ``decode_step`` continues seamlessly.
    """
    B, S = tokens.shape
    max_seq = max_seq or S
    x = L.embed(params["embed"], tokens, scale=cfg.embed_scale)
    if cfg.max_position:
        x = x + params["pos_embed"][None, :S]
    x = shard(x, rules.residual())
    positions = jnp.arange(S)
    if cfg.encoder_layers and cross_src is not None:
        cross_src = encode(cfg, params, cross_src, rules=rules, mesh=mesh)

    cache = []
    for pi, pat in enumerate(cfg.patterns):
        stage_params = tuple(params["blocks"][pi])

        def repeat_body(x, lps, _pat=pat):
            outs = []
            for j, spec in enumerate(_pat.stages):
                def scan_body(h, lp, _spec=spec):
                    h2, c = _layer_prefill(cfg, _spec, lp, h,
                                           positions=positions,
                                           max_seq=max_seq,
                                           cross_src=cross_src, rules=rules,
                                           mesh=mesh)
                    return h2, c
                x, cs = lax.scan(scan_body, x, lps[j])
                outs.append(cs)
            return x, tuple(outs)

        x, pat_caches = lax.scan(repeat_body, x, stage_params)
        cache.append(list(pat_caches))
    x = _norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(cfg, params, x[:, -1:])[:, 0]
    return logits, cache


def _fill_kv_cache(k: jax.Array, window: int, S: int, max_seq: int):
    """Place prefill K/V rows at the slots decode_step expects.

    Global layers: slots 0..S-1 of a max_seq cache.  Windowed layers: ring
    buffer of size W=min(window, max_seq); position p lives at slot p % W.
    """
    if not window:
        if max_seq > S:
            k = jnp.pad(k, ((0, 0), (0, max_seq - S), (0, 0), (0, 0)))
        return k
    W = min(window, max_seq)
    if S < W:
        return jnp.pad(k, ((0, 0), (0, W - S), (0, 0), (0, 0)))
    kw = k[:, S - W:]
    shift = S % W            # position S-W+j -> slot (S-W+j) % W
    return jnp.roll(kw, shift, axis=1)


def _layer_prefill(cfg: ModelConfig, spec: StageSpec, lp, x, *, positions,
                   max_seq, cross_src, rules, mesh):
    """Like _layer_fwd but emits this layer's cache contribution."""
    S = x.shape[1]
    cache: dict[str, Any] = {}
    kind = spec.kind
    if kind in ("attn", "attn_cross", "hybrid"):
        h = _gnorm(lp["ln1"], x, cfg, rules)
        q, k, v = L._qkv(lp["attn"], h, n_heads=cfg.num_heads,
                         n_kv=cfg.num_kv_heads, head_dim=cfg.hd,
                         qkv_bias=cfg.qkv_bias)
        if cfg.use_rope:
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
        kf = L._repeat_kv(k, cfg.num_heads)
        vf = L._repeat_kv(v, cfg.num_heads)
        if S > 8192:
            o = L.chunked_attention(q, kf, vf, causal=True,
                                    window=spec.window)
        else:
            o = L.attention_core(q, kf, vf, causal=True, window=spec.window)
        a = o.reshape(x.shape[0], S, -1) @ lp["attn"]["wo"]
        a = shard(a, rules.residual())
        cache["k"] = shard(
            _fill_kv_cache(k.astype(cfg.dtype), spec.window, S, max_seq),
            rules.act(rules.seq, None, None))
        cache["v"] = shard(
            _fill_kv_cache(v.astype(cfg.dtype), spec.window, S, max_seq),
            rules.act(rules.seq, None, None))
        if kind == "hybrid":
            m, conv_state, ssm_state = _mamba_prefill(cfg, lp["mixer"], h)
            cache["conv"], cache["ssm"] = conv_state, ssm_state
            fused = 0.5 * (L.rms_norm(lp["attn_norm"], a, cfg.norm_eps) +
                           L.rms_norm(lp["ssm_norm"], m, cfg.norm_eps))
            x = x + fused
        else:
            x = x + a
        if kind == "attn_cross":
            h = _gnorm(lp["lnx"], x, cfg, rules)
            xk, xv = L.project_cross_kv(lp["xattn"], cross_src,
                                        n_kv=cfg.num_kv_heads,
                                        head_dim=cfg.hd,
                                        qkv_bias=cfg.qkv_bias)
            cache["xk"], cache["xv"] = (xk.astype(cfg.dtype),
                                        xv.astype(cfg.dtype))
            c = L.cross_attention(lp["xattn"], h, (xk, xv),
                                  n_heads=cfg.num_heads,
                                  n_kv=cfg.num_kv_heads, head_dim=cfg.hd,
                                  qkv_bias=cfg.qkv_bias, rules=rules)
            x = x + c
        h = _gnorm(lp["ln2"], x, cfg, rules)
        x = x + _ffn_apply(lp, h, cfg, rules, mesh)
    elif kind == "cross":
        h = _gnorm(lp["ln1"], x, cfg, rules)
        xk, xv = L.project_cross_kv(lp["xattn"], cross_src,
                                    n_kv=cfg.num_kv_heads, head_dim=cfg.hd,
                                    qkv_bias=cfg.qkv_bias)
        cache["xk"], cache["xv"] = xk.astype(cfg.dtype), xv.astype(cfg.dtype)
        c = L.cross_attention(lp["xattn"], h, (xk, xv),
                              n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                              head_dim=cfg.hd, qkv_bias=cfg.qkv_bias,
                              rules=rules)
        x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * c
        h = _gnorm(lp["ln2"], x, cfg, rules)
        x = x + jnp.tanh(lp["gate_mlp"]).astype(x.dtype) * \
            _ffn_apply(lp, h, cfg, rules, mesh)
    elif kind == "mamba":
        h = _gnorm(lp["ln1"], x, cfg, rules)
        m, conv_state, ssm_state = _mamba_prefill(cfg, lp["mixer"], h)
        cache["conv"], cache["ssm"] = conv_state, ssm_state
        x = x + m
    else:
        raise ValueError(kind)
    return shard(x, rules.residual()), cache


def _mamba_prefill(cfg: ModelConfig, mp, h):
    """Mamba over the prompt, returning output + final (conv, ssm) states."""
    xz = h @ mp["in_proj"]
    xc, z = jnp.split(xz, 2, axis=-1)
    xc_conv = jax.nn.silu(L._causal_conv(xc, mp["conv_w"], mp["conv_b"]))
    dt, Bc, Cc = L._ssm_params(mp, xc_conv, d_state=cfg.ssm_state)
    y, h_last = L.selective_scan(xc_conv, dt, Bc, Cc, mp["A_log"], mp["D"])
    y = y * jax.nn.silu(z)
    out = y @ mp["out_proj"]
    conv_state = xc[:, -(cfg.conv_kernel - 1):].astype(cfg.dtype)
    return out, conv_state, h_last
