"""Host-memory edgelist-page caches (NAVIS §7) + baseline policies.

NAVIS-cache: a *mostly-frozen region* (90% of capacity, randomized eviction
with up to 8 probes that skip recently-used entries) plus a *tiny admission
window* (10%, LRU).  A page must be hit **twice inside the window** to be
promoted to the frozen region — filtering one-off edgelists from long
exploration paths.  Inspired by TinyLFU/FrozenHot; parameters per the paper.

Baselines for Fig. 17(b): LRU, CLOCK (FIFO + second chance), LFU.

All policies are pure functions over a :class:`CacheState` pytree, so they
run inside jitted search/insert loops.  Lookup is O(1) via a direct-map
``status``/``slot_of`` table over page ids; evictions scan only the small
window (LRU argmin) or probe randomly (frozen region), mirroring the paper's
"no expensive tracking structures" argument.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

# status codes
NOT_CACHED = jnp.int8(0)
IN_WINDOW = jnp.int8(1)
IN_FROZEN = jnp.int8(2)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CacheState:
    policy: jax.Array          # int32 enum (POLICIES)
    status: jax.Array          # [P_max] int8
    hits: jax.Array            # [P_max] int32 (window hit count / LFU freq)
    slot_of: jax.Array         # [P_max] int32 slot index within its region
    window_pages: jax.Array    # [W] int32 page ids, -1 empty
    window_last: jax.Array     # [W] int32 last-access tick
    frozen_pages: jax.Array    # [F] int32 page ids, -1 empty
    frozen_last: jax.Array     # [F] int32 last-access tick (in-use guard)
    frozen_fill: jax.Array     # int32 number of occupied frozen slots
    clock_hand: jax.Array      # int32 (CLOCK policy)
    clock: jax.Array           # int32 global tick
    key: jax.Array             # PRNG key for randomized eviction


POLICIES = {"navis": 0, "lru": 1, "clock": 2, "lfu": 3, "none": 4}
_PROBES = 8          # randomized-eviction probe budget (paper default)
_INUSE_TICKS = 64    # "currently in use" guard for frozen eviction


def init_cache(p_max: int, capacity_pages: int, policy: str,
               key: jax.Array, window_frac: float = 0.10) -> CacheState:
    if policy == "navis":
        w = max(int(capacity_pages * window_frac), 1)
        f = max(capacity_pages - w, 1)
    elif policy == "none":
        w, f = 1, 1
    else:
        # single-region policies keep everything in the "window" arrays
        w, f = capacity_pages, 1
    return CacheState(
        policy=jnp.asarray(POLICIES[policy], jnp.int32),
        status=jnp.zeros((p_max,), jnp.int8),
        hits=jnp.zeros((p_max,), jnp.int32),
        slot_of=jnp.full((p_max,), -1, jnp.int32),
        window_pages=jnp.full((w,), -1, jnp.int32),
        window_last=jnp.full((w,), -1, jnp.int32),
        frozen_pages=jnp.full((f,), -1, jnp.int32),
        frozen_last=jnp.full((f,), -1, jnp.int32),
        frozen_fill=jnp.zeros((), jnp.int32),
        clock_hand=jnp.zeros((), jnp.int32),
        clock=jnp.zeros((), jnp.int32),
        key=key,
    )


# ---------------------------------------------------------------------------
# NAVIS policy
# ---------------------------------------------------------------------------

def _install_frozen(st: CacheState, page) -> CacheState:
    """Move ``page`` into the frozen region (randomized eviction with
    ``_PROBES`` probes that skip recently-used entries), dropping it from
    the window if it currently sits there."""
    key, sub = jax.random.split(st.key)
    f = st.frozen_pages.shape[0]
    # int32 explicitly: under x64 the default int64 probes would downcast
    # into the int32 slot tables on every traced access (FutureWarning)
    probes = jax.random.randint(sub, (_PROBES,), 0, f, dtype=jnp.int32)
    occupied = st.frozen_pages[probes] >= 0
    recently = (st.clock - st.frozen_last[probes]) < _INUSE_TICKS
    # prefer an empty probe, else the first not-recently-used, else probe 0
    score = jnp.where(~occupied, 0, jnp.where(~recently, 1, 2))
    victim_slot = probes[jnp.argmin(score)]
    old = st.frozen_pages[victim_slot]
    status = st.status
    slot_of = st.slot_of
    status = jnp.where(old >= 0, status.at[old].set(NOT_CACHED), status)
    slot_of = jnp.where(old >= 0, slot_of.at[old].set(-1), slot_of)
    # remove from window
    in_window = st.status[page] == IN_WINDOW
    wslot = st.slot_of[page]
    window_pages = jnp.where(in_window,
                             st.window_pages.at[wslot].set(-1),
                             st.window_pages)
    window_last = jnp.where(in_window,
                            st.window_last.at[wslot].set(-1),
                            st.window_last)
    status = status.at[page].set(IN_FROZEN)
    slot_of = slot_of.at[page].set(victim_slot)
    frozen_pages = st.frozen_pages.at[victim_slot].set(page)
    frozen_last = st.frozen_last.at[victim_slot].set(st.clock)
    fill = st.frozen_fill + jnp.where(old >= 0, 0, 1)
    return dataclasses.replace(
        st, status=status, slot_of=slot_of, window_pages=window_pages,
        window_last=window_last, frozen_pages=frozen_pages,
        frozen_last=frozen_last, frozen_fill=fill, key=key)


def _navis_hit_window(st: CacheState, page) -> CacheState:
    """Second window hit ⇒ promote to frozen (randomized eviction)."""
    slot = st.slot_of[page]
    hits = st.hits.at[page].add(1)
    window_last = st.window_last.at[slot].set(st.clock)
    st = dataclasses.replace(st, hits=hits, window_last=window_last)
    return jax.lax.cond(st.hits[page] >= 2,
                        lambda s: _install_frozen(s, page), lambda s: s, st)


def _navis_miss(st: CacheState, page) -> CacheState:
    """Admit into the window, evicting the LRU window entry."""
    # empty slots have last=-1; int32 keeps the x64 scatter cast-free
    victim = jnp.argmin(st.window_last).astype(jnp.int32)
    old = st.window_pages[victim]
    status = st.status
    slot_of = st.slot_of
    hits = st.hits
    status = jnp.where(old >= 0, status.at[old].set(NOT_CACHED), status)
    slot_of = jnp.where(old >= 0, slot_of.at[old].set(-1), slot_of)
    hits = jnp.where(old >= 0, hits.at[old].set(0), hits)
    status = status.at[page].set(IN_WINDOW)
    slot_of = slot_of.at[page].set(victim)
    hits = hits.at[page].set(1)
    return dataclasses.replace(
        st, status=status, slot_of=slot_of, hits=hits,
        window_pages=st.window_pages.at[victim].set(page),
        window_last=st.window_last.at[victim].set(st.clock))


# ---------------------------------------------------------------------------
# Baseline policies (single region in the window arrays)
# ---------------------------------------------------------------------------

def _single_region_hit(st: CacheState, page) -> CacheState:
    slot = st.slot_of[page]
    window_last = st.window_last.at[slot].set(st.clock)
    hits = st.hits.at[page].add(1)
    return dataclasses.replace(st, window_last=window_last, hits=hits)


def _single_region_miss(st: CacheState, page) -> CacheState:
    def lru_victim(st):
        return jnp.argmin(st.window_last)

    def lfu_victim(st):
        occ = st.window_pages >= 0
        freq = jnp.where(occ, st.hits[jnp.maximum(st.window_pages, 0)],
                         -1)
        return jnp.argmin(jnp.where(occ, freq, -1))

    def clock_victim(st):
        # second chance: sweep from the hand; entries with a reference bit
        # (recent access) get it cleared and are skipped
        w = st.window_pages.shape[0]
        idx = (st.clock_hand + jnp.arange(w)) % w
        ref = (st.clock - st.window_last[idx]) < _INUSE_TICKS
        first_clear = jnp.argmax(~ref)
        return idx[first_clear]

    victim = jax.lax.switch(
        jnp.clip(st.policy - 1, 0, 2),
        [lru_victim, clock_victim, lfu_victim], st).astype(jnp.int32)
    old = st.window_pages[victim]
    status = st.status
    slot_of = st.slot_of
    hits = st.hits
    status = jnp.where(old >= 0, status.at[old].set(NOT_CACHED), status)
    slot_of = jnp.where(old >= 0, slot_of.at[old].set(-1), slot_of)
    hits = jnp.where(old >= 0, hits.at[old].set(0), hits)
    status = status.at[page].set(IN_WINDOW)
    slot_of = slot_of.at[page].set(victim)
    hits = hits.at[page].set(1)
    hand = jnp.where(st.policy == POLICIES["clock"],
                     ((victim + 1) % st.window_pages.shape[0]).astype(
                         st.clock_hand.dtype), st.clock_hand)
    return dataclasses.replace(
        st, status=status, slot_of=slot_of, hits=hits,
        window_pages=st.window_pages.at[victim].set(page),
        window_last=st.window_last.at[victim].set(st.clock),
        clock_hand=hand)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def lookup(st: CacheState, page: jax.Array) -> jax.Array:
    """Pure hit test against a *frozen* cache — no mutation, no clock tick.

    This is the read half of :func:`access`, split out so a batch of
    concurrent readers — search queries or an insert wave's position
    seeks — can probe one shared snapshot under ``vmap`` (mutating
    per-access state does not vectorise; a snapshot lookup does).  The
    access sequence each reader observed is recorded as a trace and
    folded back in with :func:`apply_trace`.
    """
    return (st.status[page] != NOT_CACHED) & (st.policy != POLICIES["none"])


def apply_trace(st: CacheState, trace: jax.Array) -> tuple[jax.Array,
                                                           CacheState]:
    """Replay a page-access trace into the cache, returning (replay hit
    count, new state).  The valid entries are a contiguous prefix — the
    traversal appends charged accesses in order — so replay runs a
    dynamic-length loop that stops at the first ``-1``: cost scales with
    the accesses actually charged, not with the (heavily padded)
    ``max_hops × beam_width`` trace capacity.

    Concurrent readers share one cache: each runs against the same frozen
    snapshot, then their traces are replayed in order so the merged state
    evolves exactly as if the accesses had been issued sequentially — the
    paper's model of search threads sharing the host cache.  For a single
    trace replayed onto the snapshot it was recorded against, the result
    is bit-identical to having threaded :func:`access` through the search.
    """
    t = trace.shape[0]

    def cond(carry):
        i, _, _ = carry
        return (i < t) & (trace[jnp.minimum(i, t - 1)] >= 0)

    def body(carry):
        i, hits, st = carry
        hit, st = access(st, trace[i])
        return i + 1, hits + hit.astype(jnp.int32), st

    _, hits, st = jax.lax.while_loop(
        cond, body, (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                     st))
    return hits, st


def apply_traces(st: CacheState, traces: jax.Array) -> tuple[jax.Array,
                                                             CacheState]:
    """Replay a batch of traces ([Q, T] int32, -1-padded) in wave order.

    Both fan-out paths use this merge: ``search_many`` replays its query
    wave's traces, ``insert_many`` its position-seek traces (before the
    commit scan, whose out-of-place updates may then invalidate pages —
    all wave reads precede all wave writes in the two-phase model).
    Padding lanes replay nothing: set their rows to all ``-1``.
    """
    def step(carry, trace):
        hits, st = carry
        h, st = apply_trace(st, trace)
        return (hits + h, st), None

    (hits, st), _ = jax.lax.scan(step, (jnp.zeros((), jnp.int32), st),
                                 traces)
    return hits, st


def access(st: CacheState, page: jax.Array) -> tuple[jax.Array, CacheState]:
    """One page access.  Returns (hit: bool, new state).

    The caller charges a slow-tier read on a miss.  NAVIS refreshes the
    frozen-region in-use stamp on hits (eviction protection, §7).
    """
    st = dataclasses.replace(st, clock=st.clock + 1)
    is_none = st.policy == POLICIES["none"]
    hit = lookup(st, page)

    def on_hit(st: CacheState) -> CacheState:
        def navis(st):
            def frozen_touch(st):
                slot = st.slot_of[page]
                return dataclasses.replace(
                    st, frozen_last=st.frozen_last.at[slot].set(st.clock))
            return jax.lax.cond(st.status[page] == IN_FROZEN, frozen_touch,
                                lambda s: _navis_hit_window(s, page), st)
        return jax.lax.cond(st.policy == POLICIES["navis"], navis,
                            lambda s: _single_region_hit(s, page), st)

    def on_miss(st: CacheState) -> CacheState:
        def noop(st):
            return st
        def admit(st):
            return jax.lax.cond(st.policy == POLICIES["navis"],
                                lambda s: _navis_miss(s, page),
                                lambda s: _single_region_miss(s, page), st)
        return jax.lax.cond(is_none, noop, admit, st)

    st = jax.lax.cond(hit, on_hit, on_miss, st)
    return hit, st


def priority_admit(st: CacheState, page: jax.Array) -> CacheState:
    """Admit ``page`` straight into the frozen region, bypassing the
    two-hits-in-window filter (entrance-aware cache hint, paper §7): when
    the dynamic entrance promotes a vertex, its edgelist page is about to
    seed every traversal, so it earns frozen residency immediately.

    NAVIS policy only (single-region baselines have no frozen region to
    pin into); a page already frozen just gets its in-use stamp
    refreshed.  No I/O is charged — admission moves host memory."""
    def do(st):
        def touch(st):
            slot = st.slot_of[page]
            return dataclasses.replace(
                st, frozen_last=st.frozen_last.at[slot].set(st.clock))
        return jax.lax.cond(st.status[page] == IN_FROZEN, touch,
                            lambda s: _install_frozen(s, page), st)

    eligible = (st.policy == POLICIES["navis"]) & (page >= 0)
    return jax.lax.cond(eligible, do, lambda s: s, st)


def invalidate_page(st: CacheState, page: jax.Array) -> CacheState:
    """Eviction hint from the indirection layer when an edge page dies
    (out-of-place update invalidated every slot — §8.2)."""
    def drop(st):
        slot = st.slot_of[page]
        in_window = st.status[page] == IN_WINDOW
        window_pages = jnp.where(in_window,
                                 st.window_pages.at[slot].set(-1),
                                 st.window_pages)
        window_last = jnp.where(in_window,
                                st.window_last.at[slot].set(-1),
                                st.window_last)
        frozen_pages = jnp.where(~in_window,
                                 st.frozen_pages.at[slot].set(-1),
                                 st.frozen_pages)
        return dataclasses.replace(
            st, status=st.status.at[page].set(NOT_CACHED),
            slot_of=st.slot_of.at[page].set(-1),
            hits=st.hits.at[page].set(0),
            window_pages=window_pages, window_last=window_last,
            frozen_pages=frozen_pages)
    return jax.lax.cond(st.status[page] != NOT_CACHED, drop, lambda s: s, st)
