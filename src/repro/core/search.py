"""GVS search: entry-point selection → on-disk beam traversal (→ rerank).

The traversal is the paper's ② stage: greedy beam search over the on-disk
graph using in-memory PQ distances, loading only edgelist pages under the
decoupled layout (packed layout drags vectors along — counted).  A fixed
size explored pool (|E_search| for queries, |E_pos| for position seeking) is
maintained until convergence.

Everything is jittable: the pool, visited bitmap, cache state and I/O
counters thread through a ``lax.while_loop``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cache as cache_mod
from repro.core import pq as pq_mod
from repro.core.entrance import EntranceGraph, empty_entrance  # noqa: F401
from repro.core.iomodel import IOCounters, PAGE_BYTES
from repro.core.layout import GraphStore, LayoutSpec

INF = jnp.float32(3.4e38)


def entrance_search(ent: EntranceGraph, lut: jax.Array, codes: jax.Array,
                    *, n_entry: int, pool_size: int = 32,
                    max_hops: int = 64):
    """In-memory beam search over the entrance graph (no storage I/O).

    Returns (entry ids [n_entry] into the MAIN graph, explored-set main ids
    E_ent [pool_size] with their PQ distances) — the explored set feeds
    NAVIS-update (Algorithm 2).
    """
    c = ent.c_max
    # seed: first live entry (build keeps a medoid-ish vertex at index 0)
    seed = jnp.zeros((1,), jnp.int32)
    seed_main = ent.ids[seed]
    seed_d = jnp.where(seed_main >= 0,
                       pq_mod.adc_distance(lut, codes[jnp.maximum(
                           seed_main, 0)]), INF)

    pool_idx = jnp.full((pool_size,), -1, jnp.int32).at[0].set(seed[0])
    pool_d = jnp.full((pool_size,), INF).at[0].set(seed_d[0])
    expanded = jnp.zeros((c,), bool)

    def cond(carry):
        pool_idx, pool_d, expanded, hops = carry
        frontier = (pool_idx >= 0) & ~expanded[jnp.maximum(pool_idx, 0)]
        return (hops < max_hops) & frontier.any()

    def body(carry):
        pool_idx, pool_d, expanded, hops = carry
        cand_d = jnp.where((pool_idx >= 0) &
                           ~expanded[jnp.maximum(pool_idx, 0)], pool_d, INF)
        best = jnp.argmin(cand_d)
        v = pool_idx[best]
        expanded = expanded.at[v].set(True)
        nbrs = ent.edges[v]                                   # [R_ent]
        in_pool = (nbrs[:, None] == pool_idx[None, :]).any(axis=1)
        valid = (nbrs >= 0) & ~expanded[jnp.maximum(nbrs, 0)] & ~in_pool
        main_ids = ent.ids[jnp.maximum(nbrs, 0)]
        d = jnp.where(valid & (main_ids >= 0),
                      pq_mod.adc_distance(lut, codes[jnp.maximum(
                          main_ids, 0)]), INF)
        all_idx = jnp.concatenate([pool_idx, jnp.where(valid, nbrs, -1)])
        all_d = jnp.concatenate([pool_d, d])
        neg_d, order = lax.top_k(-all_d, pool_size)
        return (all_idx[order], -neg_d, expanded, hops + 1)

    pool_idx, pool_d, expanded, hops = lax.while_loop(
        cond, body, (pool_idx, pool_d, expanded,
                     jnp.zeros((), jnp.int32)))
    main = jnp.where(pool_idx >= 0, ent.ids[jnp.maximum(pool_idx, 0)], -1)
    return main[:n_entry], main, pool_d


# ---------------------------------------------------------------------------
# On-disk traversal
# ---------------------------------------------------------------------------

class TraverseResult(NamedTuple):
    pool_ids: jax.Array       # [pool] main-graph ids sorted by PQ distance
    pool_dists: jax.Array     # [pool] PQ distances
    vec_loaded: jax.Array     # [N_max] bool — vectors dragged in (packed)
    hops: jax.Array
    cache: cache_mod.CacheState
    counters: IOCounters
    page_seen: jax.Array      # [P_max] bool — pages this traversal read
    # frozen-cache mode only (else None): charged page accesses, in order
    trace: jax.Array | None = None       # [max_hops * W] int32, -1 padded
    trace_n: jax.Array | None = None     # int32 — valid trace entries


def _charge_page_read(counters: IOCounters, spec: LayoutSpec, *,
                      is_edge_page: jax.Array, n=1) -> IOCounters:
    """Account ``n`` 4 KiB page reads from the slow tier (n may be traced:
    the frozen fan-out path charges a whole beam's misses at once)."""
    if spec.kind == "packed":
        per = spec.packed_per_page
        payload = per * spec.packed_record_bytes
        vec = per * spec.vector_bytes
        edge = per * spec.edgelist_bytes
        # vectors counted provisionally as wasted; reranking reclassifies
        return dataclasses.replace(
            counters,
            read_requests=counters.read_requests + n,
            edge_bytes_read=counters.edge_bytes_read + n * edge,
            wasted_vec_bytes_read=counters.wasted_vec_bytes_read + n * vec,
            pad_bytes_read=counters.pad_bytes_read +
            n * (PAGE_BYTES - payload))
    per = spec.edgelists_per_page
    payload = per * spec.edgelist_bytes
    return dataclasses.replace(
        counters,
        read_requests=counters.read_requests + n,
        edge_bytes_read=counters.edge_bytes_read + n * payload,
        pad_bytes_read=counters.pad_bytes_read +
        n * (PAGE_BYTES - payload))


def _charge_access(counters: IOCounters, spec: LayoutSpec,
                   hit: jax.Array) -> IOCounters:
    """Account one cache probe: tally hit/miss, charge a page read on miss."""
    counters = dataclasses.replace(
        counters,
        cache_hits=counters.cache_hits + hit,
        cache_misses=counters.cache_misses + (~hit))
    return lax.cond(
        hit, lambda c: c,
        lambda c: _charge_page_read(c, spec, is_edge_page=True),
        counters)


def fetch_edgelists(store: GraphStore, spec: LayoutSpec,
                    cache: cache_mod.CacheState, counters: IOCounters,
                    page_seen: jax.Array, ids: jax.Array, valid: jax.Array,
                    trace: jax.Array | None = None,
                    trace_n: jax.Array | None = None):
    """Read the edge pages backing ``ids`` (beam of W vertices) through the
    per-query buffer (``page_seen``) and the host cache.  Pages already read
    by *this* traversal are free (the query holds them in its scratch
    buffer, as DiskANN-lineage systems do) — this is where the decoupled
    layout's page-level locality pays off, since ~``edgelists_per_page``
    co-traversed vertices ride on one read.  Packed layout: the page also
    carries the vertices' vectors (marked loaded by the caller).

    With ``trace``/``trace_n`` supplied the cache is treated as a *frozen
    snapshot*: hits come from :func:`cache_mod.lookup` (pure), the cache is
    returned untouched, and every charged access is appended to ``trace``
    for later :func:`cache_mod.apply_trace` replay.  This is the read path
    concurrent (vmapped) searches share.

    Returns (edges [W,R], cache, counters, page_seen, trace, trace_n).
    """
    frozen = trace is not None
    w = ids.shape[0]
    safe = jnp.maximum(ids, 0)
    pages = store.edge_page[safe]

    if frozen:
        # No mutation ordering constraint against a snapshot, so the whole
        # beam is processed vectorised (the sequential path must scan: each
        # access's eviction depends on the previous one).  The trace keeps
        # slot order, so replay still matches the sequential access order.
        safe_p = jnp.maximum(pages, 0)
        # charged if: valid, not already read by this traversal, and not a
        # duplicate of an earlier valid slot in this beam
        eq_earlier = (pages[:, None] == pages[None, :]) & valid[None, :] & \
            (jnp.arange(w)[None, :] < jnp.arange(w)[:, None])
        charged = valid & ~page_seen[safe_p] & ~eq_earlier.any(axis=1)
        hit = cache_mod.lookup(cache, safe_p) & charged
        n_hit = hit.sum()
        n_miss = charged.sum() - n_hit
        counters = dataclasses.replace(
            counters,
            cache_hits=counters.cache_hits + n_hit,
            cache_misses=counters.cache_misses + n_miss)
        counters = _charge_page_read(counters, spec, is_edge_page=True,
                                     n=n_miss)
        # scatter charged pages at trace_n.. in slot order (OOB = dropped)
        pos = jnp.where(charged, trace_n + jnp.cumsum(charged) - 1,
                        trace.shape[0])
        trace = trace.at[pos].set(pages)
        trace_n = trace_n + charged.sum().astype(jnp.int32)
        page_seen = page_seen.at[jnp.where(valid, safe_p,
                                           page_seen.shape[0])].set(True)
    else:
        def step(carry, i):
            cache_c, counters, page_seen = carry
            page = pages[i]
            # free if: invalid, duplicate within this beam, or already read
            # by this traversal (per-query buffer)
            earlier = jnp.arange(w) < i
            dup = jnp.any((pages == page) & valid & earlier)
            dup = dup | ~valid[i] | page_seen[jnp.maximum(page, 0)]

            def charged(args):
                cache_c, counters = args
                hit, cache_c = cache_mod.access(cache_c, page)
                return cache_c, _charge_access(counters, spec, hit)

            cache_c, counters = lax.cond(dup, lambda a: a, charged,
                                         (cache_c, counters))
            page_seen = page_seen.at[jnp.maximum(page, 0)].set(
                page_seen[jnp.maximum(page, 0)] | valid[i])
            return (cache_c, counters, page_seen), None

        (cache, counters, page_seen), _ = lax.scan(
            step, (cache, counters, page_seen), jnp.arange(w))
    edges = jnp.where(valid[:, None], store.edges[safe], -1)
    return edges, cache, counters, page_seen, trace, trace_n


def disk_traverse(store: GraphStore, spec: LayoutSpec, lut: jax.Array,
                  codes: jax.Array, cache: cache_mod.CacheState,
                  counters: IOCounters, entry_ids: jax.Array, *,
                  pool_size: int, beam_width: int = 4,
                  max_hops: int = 512,
                  page_seen: jax.Array | None = None,
                  frozen_cache: bool = False) -> TraverseResult:
    """Greedy beam search over the on-disk graph with PQ distances.

    ``entry_ids``: [n_entry] main-graph ids (-1 padded) from ① entry-point
    selection.  Pool converges when no unexpanded candidate remains among
    the top ``pool_size``.  ``page_seen`` optionally seeds the per-query
    page buffer (bulk merges share one buffer across many seeks so repeated
    page reads amortise — FreshDiskANN's batched-I/O advantage).

    ``frozen_cache=True`` runs the traversal as a pure *reader* of the
    cache snapshot: no cache mutation threads through the loop (so a batch
    of traversals vectorises under ``vmap``), and the charged page-access
    sequence comes back in ``result.trace`` / ``result.trace_n`` for
    ordered replay into the shared cache afterwards.  Both fan-outs ride
    on this: ``search_many`` (|E_search| pools) and ``insert_many``'s
    position-seek phase (|E_pos| pools via :func:`insert.position_seek`).
    """
    n_max = store.n_max
    n_entry = entry_ids.shape[0]

    safe_e = jnp.maximum(entry_ids, 0)
    e_valid = entry_ids >= 0
    e_d = jnp.where(e_valid, pq_mod.adc_distance(lut, codes[safe_e]), INF)
    order = jnp.argsort(e_d)
    pool_ids = jnp.full((pool_size,), -1, jnp.int32)
    pool_d = jnp.full((pool_size,), INF)
    k = min(n_entry, pool_size)
    pool_ids = pool_ids.at[:k].set(
        jnp.where(e_valid[order][:k], entry_ids[order][:k], -1))
    pool_d = pool_d.at[:k].set(e_d[order][:k])
    expanded = jnp.zeros((n_max,), bool)
    vec_loaded = jnp.zeros((n_max,), bool)
    if page_seen is None:
        page_seen = jnp.zeros_like(store.page_live, dtype=bool)
    if frozen_cache:
        # each hop charges ≤ beam_width accesses, so this never overflows
        trace0 = jnp.full((max_hops * beam_width,), -1, jnp.int32)
        trace_n0 = jnp.zeros((), jnp.int32)
    else:
        trace0, trace_n0 = None, None

    def cond(carry):
        pool_ids, hops = carry[0], carry[-1]
        expanded = carry[2]
        frontier = (pool_ids >= 0) & ~expanded[jnp.maximum(pool_ids, 0)]
        return (hops < max_hops) & frontier.any()

    def body(carry):
        if frozen_cache:
            (pool_ids, pool_d, expanded, vec_loaded, page_seen,
             trace, trace_n, counters, hops) = carry
            cache_in = cache                  # closed-over snapshot
        else:
            (pool_ids, pool_d, expanded, vec_loaded, page_seen,
             cache_in, counters, hops) = carry
            trace, trace_n = None, None
        unexp = (pool_ids >= 0) & ~expanded[jnp.maximum(pool_ids, 0)]
        cand_d = jnp.where(unexp, pool_d, INF)
        # top_k (stable, like argsort) is O(n) selection, not a full sort
        neg_sel, sel = lax.top_k(-cand_d, beam_width)
        beam = jnp.where(-neg_sel < INF, pool_ids[sel], -1)
        beam_valid = beam >= 0
        expanded = expanded.at[jnp.maximum(beam, 0)].set(
            expanded[jnp.maximum(beam, 0)] | beam_valid)

        edges, cache_out, counters, page_seen, trace, trace_n = \
            fetch_edgelists(store, spec, cache_in, counters, page_seen,
                            beam, beam_valid, trace, trace_n)
        if spec.kind == "packed":
            vec_loaded = vec_loaded.at[jnp.maximum(beam, 0)].set(
                vec_loaded[jnp.maximum(beam, 0)] | beam_valid)

        # Vamana semantics: the explored pool is a *set* — candidates evicted
        # from it may be re-scored and re-enter later; only expansion is
        # permanent (marking visited-on-scoring would permanently ban evicted
        # near-misses and measurably hurt recall at wide beams).
        nbrs = edges.reshape(-1)                              # [W*R]
        safe_n = jnp.maximum(nbrs, 0)
        in_pool = (nbrs[:, None] == pool_ids[None, :]).any(axis=1)
        nvalid = (nbrs >= 0) & ~expanded[safe_n] & ~in_pool
        # dedupe within the flat neighbor list (first occurrence wins):
        # sort the W*R keys instead of scattering through an O(n_max)
        # position table — the stable sort keeps the lowest flat index
        # first among equal keys, so the same occurrence survives
        key_ = jnp.where(nvalid, nbrs, jnp.iinfo(jnp.int32).max)
        sort_idx = jnp.argsort(key_)
        sorted_key = key_[sort_idx]
        first = jnp.concatenate([
            jnp.ones((1,), bool), sorted_key[1:] != sorted_key[:-1]])
        keep = jnp.zeros_like(nvalid).at[sort_idx].set(first)
        nvalid = nvalid & keep
        nd = jnp.where(nvalid, pq_mod.adc_distance(lut, codes[safe_n]), INF)

        all_ids = jnp.concatenate([pool_ids, jnp.where(nvalid, nbrs, -1)])
        all_d = jnp.concatenate([pool_d, nd])
        neg_d, order = lax.top_k(-all_d, pool_size)
        pool_ids, pool_d = all_ids[order], -neg_d
        counters = dataclasses.replace(counters, hops=counters.hops + 1)
        if frozen_cache:
            return (pool_ids, pool_d, expanded, vec_loaded, page_seen,
                    trace, trace_n, counters, hops + 1)
        return (pool_ids, pool_d, expanded, vec_loaded, page_seen,
                cache_out, counters, hops + 1)

    if frozen_cache:
        carry = (pool_ids, pool_d, expanded, vec_loaded, page_seen,
                 trace0, trace_n0, counters, jnp.zeros((), jnp.int32))
        (pool_ids, pool_d, expanded, vec_loaded, page_seen, trace,
         trace_n, counters, hops) = lax.while_loop(cond, body, carry)
        return TraverseResult(pool_ids, pool_d, vec_loaded, hops, cache,
                              counters, page_seen, trace, trace_n)
    carry = (pool_ids, pool_d, expanded, vec_loaded, page_seen,
             cache, counters, jnp.zeros((), jnp.int32))
    pool_ids, pool_d, expanded, vec_loaded, page_seen, cache, \
        counters, hops = lax.while_loop(cond, body, carry)
    return TraverseResult(pool_ids, pool_d, vec_loaded, hops, cache,
                          counters, page_seen)


# ---------------------------------------------------------------------------
# Full-rerank baseline (packed layout: vectors already piggybacked)
# ---------------------------------------------------------------------------

def full_rerank(store: GraphStore, spec: LayoutSpec, q: jax.Array,
                res: TraverseResult, counters: IOCounters, *, k: int):
    """Exact-rerank every pool candidate (the non-CASR baseline).

    Under the packed layout the vectors rode along with the edge pages
    (zero extra I/O); under the decoupled layout this costs one vector read
    per candidate — the naïve-unpacking strawman of §3.1.
    """
    ids = res.pool_ids
    valid = ids >= 0
    safe = jnp.maximum(ids, 0)
    if spec.kind == "decoupled":
        n_loads = valid.sum()
        pages = spec.vector_pages_per_read
        counters = dataclasses.replace(
            counters,
            read_requests=counters.read_requests + n_loads,
            wasted_vec_bytes_read=counters.wasted_vec_bytes_read +
            n_loads * pages * PAGE_BYTES)
        vec_loaded = res.vec_loaded.at[safe].set(
            res.vec_loaded[safe] | valid)
    else:
        vec_loaded = res.vec_loaded
    d = jnp.where(valid, pq_mod.exact_l2(q, store.vectors[safe]), INF)
    order = jnp.argsort(d)
    return ids[order][:k], d[order][:k], vec_loaded, counters
