"""GVS search: entry-point selection → on-disk beam traversal (→ rerank).

The traversal is the paper's ② stage: greedy beam search over the on-disk
graph using in-memory PQ distances, loading only edgelist pages under the
decoupled layout (packed layout drags vectors along — counted).  A fixed
size explored pool (|E_search| for queries, |E_pos| for position seeking) is
maintained until convergence.

Everything is jittable: the pool, visited sets, cache state and I/O
counters thread through a ``lax.while_loop``.

Traversal state is O(1) in the corpus: the ``expanded`` / ``vec_loaded`` /
``page_seen`` sets are fixed-capacity hash sets bounded by the search
frontier (``max_hops × beam_width`` marks — see :mod:`repro.core.visited`),
not ``[n_max]`` bitmaps, so a B-lane fan-out wave costs
``O(B·max_hops·beam_width)`` memory instead of ``O(B·n_max)``.  The
``visited="bitmap"`` mode keeps the dense reference implementation
(equivalence tests / ablation).  Per-hop examination compute (ADC
distances, exact L2, pool merge) routes through the backend-dispatched
kernel layer (:mod:`repro.kernels.ops`): Pallas Mosaic on TPU, the jnp
oracles elsewhere.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cache as cache_mod
from repro.core import visited as visited_mod
from repro.core.entrance import EntranceGraph, empty_entrance  # noqa: F401
from repro.core.iomodel import IOCounters, PAGE_BYTES
from repro.core.layout import GraphStore, LayoutSpec
from repro.kernels import ops as kernel_ops

INF = jnp.float32(3.4e38)


def entrance_search(ent: EntranceGraph, lut: jax.Array, codes: jax.Array,
                    *, n_entry: int, pool_size: int = 32,
                    max_hops: int = 64, visited: str = "hash"):
    """In-memory beam search over the entrance graph (no storage I/O).

    Returns (entry ids [n_entry] into the MAIN graph, explored-set main ids
    E_ent [pool_size] with their PQ distances) — the explored set feeds
    NAVIS-update (Algorithm 2).

    The ``expanded`` set is a hash set of ≤ ``min(max_hops, c_max)`` slots
    (one expansion per hop), so per-query state does not scale with the
    entrance graph; ``visited="bitmap"`` keeps the dense reference.
    """
    c = ent.c_max
    # seed: the first *live* entry slot.  Build keeps a medoid-ish vertex at
    # slot 0, but deletes scrub entrance members — after the medoid dies the
    # seed must fall back to the next live slot, not a dead one.
    live = ent.ids >= 0
    seed = jnp.argmax(live).astype(jnp.int32)[None]
    seed_main = ent.ids[seed]
    seed_d = jnp.where(seed_main >= 0,
                       kernel_ops.adc_distance(lut, codes[jnp.maximum(
                           seed_main, 0)]), INF)

    pool_idx = jnp.full((pool_size,), -1, jnp.int32).at[0].set(seed[0])
    pool_d = jnp.full((pool_size,), INF).at[0].set(seed_d[0])
    if visited == "bitmap":
        expanded = visited_mod.make_dense(c)
    else:
        # one expansion per hop, at most c distinct slots: never overflows
        expanded = visited_mod.make_hash(min(max_hops, c))
    unexp0 = pool_idx >= 0

    def cond(carry):
        unexp, hops = carry[3], carry[4]
        return (hops < max_hops) & unexp.any()

    def body(carry):
        pool_idx, pool_d, expanded, unexp, hops = carry
        cand_d = jnp.where(unexp, pool_d, INF)
        best = jnp.argmin(cand_d)
        v = pool_idx[best]
        expanded = visited_mod.add(expanded, v[None], jnp.ones((1,), bool))
        nbrs = ent.edges[v]                                   # [R_ent]
        in_pool = (nbrs[:, None] == pool_idx[None, :]).any(axis=1)
        valid = (nbrs >= 0) & ~visited_mod.contains(expanded, nbrs) & \
            ~in_pool
        main_ids = ent.ids[jnp.maximum(nbrs, 0)]
        d = jnp.where(valid & (main_ids >= 0),
                      kernel_ops.adc_distance(lut, codes[jnp.maximum(
                          main_ids, 0)]), INF)
        pool_d, pool_idx = kernel_ops.pool_merge(
            pool_d, pool_idx, d, jnp.where(valid, nbrs, -1))
        unexp = (pool_idx >= 0) & ~visited_mod.contains(expanded, pool_idx)
        return (pool_idx, pool_d, expanded, unexp, hops + 1)

    pool_idx, pool_d, expanded, _, hops = lax.while_loop(
        cond, body, (pool_idx, pool_d, expanded, unexp0,
                     jnp.zeros((), jnp.int32)))
    main = jnp.where(pool_idx >= 0, ent.ids[jnp.maximum(pool_idx, 0)], -1)
    return main[:n_entry], main, pool_d


# ---------------------------------------------------------------------------
# On-disk traversal
# ---------------------------------------------------------------------------

class TraverseResult(NamedTuple):
    pool_ids: jax.Array       # [pool] main-graph ids sorted by PQ distance
    pool_dists: jax.Array     # [pool] PQ distances
    vec_loaded: visited_mod.VisitedSet   # vectors dragged in (packed)
    hops: jax.Array
    cache: cache_mod.CacheState
    counters: IOCounters
    # pages this traversal read: a VisitedSet, or a raw [P_max] bool array
    # when the caller seeded one (bulk-merge sharing) / bitmap mode
    page_seen: jax.Array | visited_mod.VisitedSet
    # frozen-cache mode only (else None): charged page accesses, in order
    trace: jax.Array | None = None       # [max_hops * W] int32, -1 padded
    trace_n: jax.Array | None = None     # int32 — valid trace entries


def _charge_page_read(counters: IOCounters, spec: LayoutSpec, *,
                      is_edge_page: jax.Array, n=1) -> IOCounters:
    """Account ``n`` 4 KiB page reads from the slow tier (n may be traced:
    the frozen fan-out path charges a whole beam's misses at once)."""
    if spec.kind == "packed":
        per = spec.packed_per_page
        payload = per * spec.packed_record_bytes
        vec = per * spec.vector_bytes
        edge = per * spec.edgelist_bytes
        # vectors counted provisionally as wasted; reranking reclassifies
        return dataclasses.replace(
            counters,
            read_requests=counters.read_requests + n,
            edge_bytes_read=counters.edge_bytes_read + n * edge,
            wasted_vec_bytes_read=counters.wasted_vec_bytes_read + n * vec,
            pad_bytes_read=counters.pad_bytes_read +
            n * (PAGE_BYTES - payload))
    per = spec.edgelists_per_page
    payload = per * spec.edgelist_bytes
    return dataclasses.replace(
        counters,
        read_requests=counters.read_requests + n,
        edge_bytes_read=counters.edge_bytes_read + n * payload,
        pad_bytes_read=counters.pad_bytes_read +
        n * (PAGE_BYTES - payload))


def _charge_access(counters: IOCounters, spec: LayoutSpec,
                   hit: jax.Array) -> IOCounters:
    """Account one cache probe: tally hit/miss, charge a page read on miss."""
    counters = dataclasses.replace(
        counters,
        cache_hits=counters.cache_hits + hit,
        cache_misses=counters.cache_misses + (~hit))
    return lax.cond(
        hit, lambda c: c,
        lambda c: _charge_page_read(c, spec, is_edge_page=True),
        counters)


def fetch_edgelists(store: GraphStore, spec: LayoutSpec,
                    cache: cache_mod.CacheState, counters: IOCounters,
                    page_seen: visited_mod.VisitedSet, ids: jax.Array,
                    valid: jax.Array,
                    trace: jax.Array | None = None,
                    trace_n: jax.Array | None = None):
    """Read the edge pages backing ``ids`` (beam of W vertices) through the
    per-query buffer (``page_seen``, a visited set) and the host cache.
    Pages already read by *this* traversal are free (the query holds them in
    its scratch buffer, as DiskANN-lineage systems do) — this is where the
    decoupled layout's page-level locality pays off, since
    ~``edgelists_per_page`` co-traversed vertices ride on one read.  Packed
    layout: the page also carries the vertices' vectors (marked loaded by
    the caller).

    With ``trace``/``trace_n`` supplied the cache is treated as a *frozen
    snapshot*: hits come from :func:`cache_mod.lookup` (pure), the cache is
    returned untouched, and every charged access is appended to ``trace``
    for later :func:`cache_mod.apply_trace` replay.  This is the read path
    concurrent (vmapped) searches share.

    Returns (edges [W,R], cache, counters, page_seen, trace, trace_n).
    """
    frozen = trace is not None
    w = ids.shape[0]
    safe = jnp.maximum(ids, 0)
    pages = store.edge_page[safe]

    if frozen:
        # No mutation ordering constraint against a snapshot, so the whole
        # beam is processed vectorised (the sequential path must scan: each
        # access's eviction depends on the previous one).  The trace keeps
        # slot order, so replay still matches the sequential access order.
        safe_p = jnp.maximum(pages, 0)
        # charged if: valid, not already read by this traversal, and not a
        # duplicate of an earlier valid slot in this beam
        eq_earlier = (pages[:, None] == pages[None, :]) & valid[None, :] & \
            (jnp.arange(w)[None, :] < jnp.arange(w)[:, None])
        charged = valid & ~visited_mod.contains(page_seen, pages) & \
            ~eq_earlier.any(axis=1)
        hit = cache_mod.lookup(cache, safe_p) & charged
        n_hit = hit.sum()
        n_miss = charged.sum() - n_hit
        counters = dataclasses.replace(
            counters,
            cache_hits=counters.cache_hits + n_hit,
            cache_misses=counters.cache_misses + n_miss)
        counters = _charge_page_read(counters, spec, is_edge_page=True,
                                     n=n_miss)
        # scatter charged pages at trace_n.. in slot order (OOB = dropped)
        pos = jnp.where(charged, trace_n + jnp.cumsum(charged) - 1,
                        trace.shape[0])
        trace = trace.at[pos].set(pages)
        trace_n = trace_n + charged.sum().astype(jnp.int32)
        page_seen = visited_mod.add(page_seen, pages, valid)
    else:
        def step(carry, i):
            cache_c, counters, page_seen = carry
            page = pages[i]
            # free if: invalid, duplicate within this beam, or already read
            # by this traversal (per-query buffer)
            earlier = jnp.arange(w) < i
            dup = jnp.any((pages == page) & valid & earlier)
            dup = dup | ~valid[i] | visited_mod.contains(page_seen, page)

            def charged(args):
                cache_c, counters = args
                hit, cache_c = cache_mod.access(cache_c, page)
                return cache_c, _charge_access(counters, spec, hit)

            cache_c, counters = lax.cond(dup, lambda a: a, charged,
                                         (cache_c, counters))
            page_seen = visited_mod.add(page_seen, page[None],
                                        valid[i][None])
            return (cache_c, counters, page_seen), None

        (cache, counters, page_seen), _ = lax.scan(
            step, (cache, counters, page_seen), jnp.arange(w))
    edges = jnp.where(valid[:, None], store.edges[safe], -1)
    return edges, cache, counters, page_seen, trace, trace_n


def make_traversal_state(*, visited: str, pool_size: int, beam_width: int,
                         max_hops: int, n_max: int, p_max: int,
                         visited_capacity: int | None = None,
                         frozen: bool = False):
    """The per-query traversal state ``disk_traverse`` carries — the ONE
    place the capacity recipe lives (``traversal_state_bytes`` and the
    footprint benchmark account the same structures).

    Expansion marks ≤ ``beam_width`` ids/pages per hop for ≤ ``max_hops``
    hops, so ``max_hops × beam_width`` bounds ``expanded``/``page_seen``
    exactly; ``vec_loaded`` additionally absorbs ``full_rerank`` marking
    the surviving pool.  Returns (expanded, vec_loaded, page_seen, trace)
    — ``trace`` is None unless ``frozen``.
    """
    cap = (visited_capacity if visited_capacity is not None
           else max_hops * beam_width)
    if visited == "bitmap":
        sets = (visited_mod.make_dense(n_max),
                visited_mod.make_dense(n_max),
                visited_mod.make_dense(p_max))
    else:
        sets = (visited_mod.make_hash(cap),
                visited_mod.make_hash(cap + pool_size),
                visited_mod.make_hash(cap))
    trace = (jnp.full((max_hops * beam_width,), -1, jnp.int32)
             if frozen else None)
    return sets + (trace,)


def _wrap_page_seen(page_seen, default: visited_mod.VisitedSet,
                    visited: str):
    """Normalise the caller's page buffer into a visited set.

    Returns (set, raw) — ``raw=True`` when the result must be handed back
    as a raw dense bool array (caller seeded one for bulk-merge sharing,
    or legacy bitmap mode)."""
    if page_seen is None:
        return default, visited == "bitmap"
    if isinstance(page_seen, (visited_mod.DenseVisited,
                              visited_mod.HashVisited)):
        return page_seen, False
    return visited_mod.DenseVisited(page_seen), True


def empty_page_seen(store: GraphStore, *, visited: str = "hash",
                    max_hops: int, beam_width: int,
                    visited_capacity: int | None = None):
    """An empty per-query page buffer matching what ``disk_traverse`` would
    create for these parameters (callers that need a structurally matching
    placeholder, e.g. masked branches of an insert)."""
    _, _, ps, _ = make_traversal_state(
        visited=visited, pool_size=1, beam_width=beam_width,
        max_hops=max_hops, n_max=store.n_max,
        p_max=store.page_live.shape[0], visited_capacity=visited_capacity)
    return ps.bits if visited == "bitmap" else ps


def disk_traverse(store: GraphStore, spec: LayoutSpec, lut: jax.Array,
                  codes: jax.Array, cache: cache_mod.CacheState,
                  counters: IOCounters, entry_ids: jax.Array, *,
                  pool_size: int, beam_width: int = 4,
                  max_hops: int = 512,
                  page_seen=None,
                  frozen_cache: bool = False,
                  visited: str = "hash",
                  visited_capacity: int | None = None) -> TraverseResult:
    """Greedy beam search over the on-disk graph with PQ distances.

    ``entry_ids``: [n_entry] main-graph ids (-1 padded) from ① entry-point
    selection.  Pool converges when no unexpanded candidate remains among
    the top ``pool_size``.  ``page_seen`` optionally seeds the per-query
    page buffer (bulk merges share one buffer across many seeks so repeated
    page reads amortise — FreshDiskANN's batched-I/O advantage); it may be
    a raw dense bool array or a :mod:`repro.core.visited` set.

    ``frozen_cache=True`` runs the traversal as a pure *reader* of the
    cache snapshot: no cache mutation threads through the loop (so a batch
    of traversals vectorises under ``vmap``), and the charged page-access
    sequence comes back in ``result.trace`` / ``result.trace_n`` for
    ordered replay into the shared cache afterwards.  Both fan-outs ride
    on this: ``search_many`` (|E_search| pools) and ``insert_many``'s
    position-seek phase (|E_pos| pools via :func:`insert.position_seek`).

    ``visited="hash"`` (default) bounds per-query state by the frontier:
    expansion marks at most ``beam_width`` ids per hop, so
    ``max_hops × beam_width`` is an exact capacity bound and the hash sets
    behave bit-identically to the ``visited="bitmap"`` reference.
    ``visited_capacity`` overrides the bound (smaller values saturate: the
    traversal may re-expand vertices, re-charging I/O — counted in
    ``counters.visited_overflow`` — but results stay well-formed).
    """
    n_max = store.n_max
    n_entry = entry_ids.shape[0]

    safe_e = jnp.maximum(entry_ids, 0)
    e_valid = entry_ids >= 0
    e_d = jnp.where(e_valid, kernel_ops.adc_distance(lut, codes[safe_e]),
                    INF)
    order = jnp.argsort(e_d)
    pool_ids = jnp.full((pool_size,), -1, jnp.int32)
    pool_d = jnp.full((pool_size,), INF)
    k = min(n_entry, pool_size)
    pool_ids = pool_ids.at[:k].set(
        jnp.where(e_valid[order][:k], entry_ids[order][:k], -1))
    pool_d = pool_d.at[:k].set(e_d[order][:k])
    expanded, vec_loaded, default_ps, trace0 = make_traversal_state(
        visited=visited, pool_size=pool_size, beam_width=beam_width,
        max_hops=max_hops, n_max=n_max, p_max=store.page_live.shape[0],
        visited_capacity=visited_capacity, frozen=frozen_cache)
    ps, raw_pages = _wrap_page_seen(page_seen, default_ps, visited)
    ovf0 = visited_mod.overflow(ps)
    # each hop charges ≤ beam_width accesses, so the trace never overflows
    trace_n0 = jnp.zeros((), jnp.int32) if frozen_cache else None
    unexp0 = pool_ids >= 0

    def cond(carry):
        unexp, hops = carry[2], carry[-1]
        return (hops < max_hops) & unexp.any()

    def body(carry):
        if frozen_cache:
            (pool_ids, pool_d, unexp, expanded, vec_loaded, ps,
             trace, trace_n, counters, hops) = carry
            cache_in = cache                  # closed-over snapshot
        else:
            (pool_ids, pool_d, unexp, expanded, vec_loaded, ps,
             cache_in, counters, hops) = carry
            trace, trace_n = None, None
        cand_d = jnp.where(unexp, pool_d, INF)
        # top_k (stable, like argsort) is O(n) selection, not a full sort
        neg_sel, sel = lax.top_k(-cand_d, beam_width)
        beam = jnp.where(-neg_sel < INF, pool_ids[sel], -1)
        beam_valid = beam >= 0
        expanded = visited_mod.add(expanded, beam, beam_valid)

        edges, cache_out, counters, ps, trace, trace_n = \
            fetch_edgelists(store, spec, cache_in, counters, ps,
                            beam, beam_valid, trace, trace_n)
        if spec.kind == "packed":
            vec_loaded = visited_mod.add(vec_loaded, beam, beam_valid)

        # Vamana semantics: the explored pool is a *set* — candidates evicted
        # from it may be re-scored and re-enter later; only expansion is
        # permanent (marking visited-on-scoring would permanently ban evicted
        # near-misses and measurably hurt recall at wide beams).
        nbrs = edges.reshape(-1)                              # [W*R]
        safe_n = jnp.maximum(nbrs, 0)
        in_pool = (nbrs[:, None] == pool_ids[None, :]).any(axis=1)
        nvalid = (nbrs >= 0) & ~visited_mod.contains(expanded, nbrs) & \
            ~in_pool
        # dedupe within the flat neighbor list (first occurrence wins):
        # sort the W*R keys instead of scattering through an O(n_max)
        # position table — the stable sort keeps the lowest flat index
        # first among equal keys, so the same occurrence survives
        key_ = jnp.where(nvalid, nbrs, jnp.iinfo(jnp.int32).max)
        sort_idx = jnp.argsort(key_)
        sorted_key = key_[sort_idx]
        first = jnp.concatenate([
            jnp.ones((1,), bool), sorted_key[1:] != sorted_key[:-1]])
        keep = jnp.zeros_like(nvalid).at[sort_idx].set(first)
        nvalid = nvalid & keep
        nd = jnp.where(nvalid,
                       kernel_ops.adc_distance(lut, codes[safe_n]), INF)

        pool_d, pool_ids = kernel_ops.pool_merge(
            pool_d, pool_ids, nd, jnp.where(nvalid, nbrs, -1))
        unexp = (pool_ids >= 0) & ~visited_mod.contains(expanded, pool_ids)
        counters = dataclasses.replace(counters, hops=counters.hops + 1)
        if frozen_cache:
            return (pool_ids, pool_d, unexp, expanded, vec_loaded, ps,
                    trace, trace_n, counters, hops + 1)
        return (pool_ids, pool_d, unexp, expanded, vec_loaded, ps,
                cache_out, counters, hops + 1)

    if frozen_cache:
        carry = (pool_ids, pool_d, unexp0, expanded, vec_loaded, ps,
                 trace0, trace_n0, counters, jnp.zeros((), jnp.int32))
        (pool_ids, pool_d, _, expanded, vec_loaded, ps, trace,
         trace_n, counters, hops) = lax.while_loop(cond, body, carry)
        cache_out = cache
    else:
        carry = (pool_ids, pool_d, unexp0, expanded, vec_loaded, ps,
                 cache, counters, jnp.zeros((), jnp.int32))
        (pool_ids, pool_d, _, expanded, vec_loaded, ps, cache_out,
         counters, hops) = lax.while_loop(cond, body, carry)
        trace, trace_n = None, None
    ovf = (visited_mod.overflow(expanded) + visited_mod.overflow(vec_loaded)
           + visited_mod.overflow(ps) - ovf0).astype(jnp.int64)
    counters = dataclasses.replace(
        counters, visited_overflow=counters.visited_overflow + ovf)
    return TraverseResult(pool_ids, pool_d, vec_loaded, hops, cache_out,
                          counters, ps.bits if raw_pages else ps,
                          trace, trace_n)


# ---------------------------------------------------------------------------
# Per-query traversal state accounting (footprint benchmark / tests)
# ---------------------------------------------------------------------------

def traversal_state_bytes(*, n_max: int, p_max: int, pool_size: int,
                          beam_width: int, max_hops: int,
                          visited: str = "hash",
                          frozen: bool = False) -> int:
    """Bytes of per-query traversal state ``disk_traverse`` carries
    (expanded + vec_loaded + page_seen, + the trace in frozen fan-out
    mode) — accounted over the very structures :func:`make_traversal_state`
    hands the traversal, so this cannot drift from the implementation.
    Pure shape math via ``eval_shape`` — nothing is allocated, so
    million-vector hypotheticals are free."""
    def build():
        return make_traversal_state(
            visited=visited, pool_size=pool_size, beam_width=beam_width,
            max_hops=max_hops, n_max=n_max, p_max=p_max, frozen=frozen)

    shapes = jax.tree.leaves(jax.eval_shape(build))
    return int(sum(math.prod(s.shape) * s.dtype.itemsize for s in shapes))


# ---------------------------------------------------------------------------
# Full-rerank baseline (packed layout: vectors already piggybacked)
# ---------------------------------------------------------------------------

def full_rerank(store: GraphStore, spec: LayoutSpec, q: jax.Array,
                res: TraverseResult, counters: IOCounters, *, k: int):
    """Exact-rerank every pool candidate (the non-CASR baseline).

    Under the packed layout the vectors rode along with the edge pages
    (zero extra I/O); under the decoupled layout this costs one vector read
    per candidate — the naïve-unpacking strawman of §3.1.
    """
    ids = res.pool_ids
    valid = ids >= 0
    safe = jnp.maximum(ids, 0)
    if spec.kind == "decoupled":
        n_loads = valid.sum()
        pages = spec.vector_pages_per_read
        counters = dataclasses.replace(
            counters,
            read_requests=counters.read_requests + n_loads,
            wasted_vec_bytes_read=counters.wasted_vec_bytes_read +
            n_loads * pages * PAGE_BYTES)
        vec_loaded = visited_mod.add(res.vec_loaded, ids, valid)
        ovf = (visited_mod.overflow(vec_loaded) -
               visited_mod.overflow(res.vec_loaded)).astype(jnp.int64)
        counters = dataclasses.replace(
            counters, visited_overflow=counters.visited_overflow + ovf)
    else:
        vec_loaded = res.vec_loaded
    d = jnp.where(valid, kernel_ops.rerank_l2(q, store.vectors[safe]), INF)
    order = jnp.argsort(d)
    return ids[order][:k], d[order][:k], vec_loaded, counters
