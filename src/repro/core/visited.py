"""O(1)-state visited sets for graph traversals.

Every ``disk_traverse`` lane used to carry ``expanded`` / ``vec_loaded``
bitmaps of shape ``[n_max]`` (plus ``page_seen [p_max]``), so a B-query
fan-out wave allocated ``B × n_max`` booleans — per-wave memory grew with
the *corpus*, capping the batch sizes the fan-outs could run.  Real
on-disk GVS systems bound visited-set state by the search *frontier*: a
traversal expands at most ``beam_width`` vertices per hop for at most
``max_hops`` hops, so the number of distinct marks is exactly bounded by
``max_hops × beam_width`` regardless of index size.

:class:`HashVisited` is a fixed-capacity open-addressing (linear-probing)
hash set sized to 2× that exact bound (load factor ≤ 0.5, power-of-two
table, Fibonacci hashing).  Probing walks the table with early exit and is
capped at the table size, so an insert fails **only when the table is
truly full** — impossible when the capacity honours the mark bound, which
makes the hashed traversal bit-identical to the bitmap one.  If a caller
forces a smaller capacity the set *saturates*: the insert is dropped,
``overflow`` increments (surfaced as ``IOCounters.visited_overflow``),
and a later membership test may miss — the traversal then re-expands the
vertex, which only re-charges I/O; results are never corrupted.

:class:`DenseVisited` wraps the original ``[n]`` bitmap behind the same
``contains`` / ``add`` API — kept as the reference implementation for the
equivalence tests and the ``visited_impl="bitmap"`` engine ablation.

All operations are pure pytree functions, safe under ``jit`` / ``vmap`` /
``lax.while_loop`` carries.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

_FIB = jnp.uint32(2654435761)          # 2^32 / golden ratio (Fibonacci hash)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DenseVisited:
    """Reference bitmap: O(n) state, O(1) ops."""

    bits: jax.Array            # [n] bool


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HashVisited:
    """Open-addressing set: O(capacity) state independent of the corpus."""

    keys: jax.Array            # [table] int32, -1 = empty (power-of-two size)
    count: jax.Array           # int32 — live keys
    overflow: jax.Array        # int32 — dropped inserts (saturation events)


VisitedSet = DenseVisited | HashVisited


def table_size(capacity: int) -> int:
    """Power-of-two table ≥ 2 × capacity (load factor ≤ 0.5)."""
    cap = max(int(capacity), 1)
    return max(8, 1 << math.ceil(math.log2(2 * cap)))


def make_hash(capacity: int) -> HashVisited:
    return HashVisited(
        keys=jnp.full((table_size(capacity),), -1, jnp.int32),
        count=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.int32))


def make_dense(n: int) -> DenseVisited:
    return DenseVisited(bits=jnp.zeros((n,), bool))


def _hash(keys: jax.Array, size: int) -> jax.Array:
    """Fibonacci hash into [0, size): multiply, keep the high bits (the low
    bits of a Fibonacci multiply are poorly mixed)."""
    lg = size.bit_length() - 1
    h = keys.astype(jnp.uint32) * _FIB
    return (h >> jnp.uint32(32 - lg)).astype(jnp.int32)


def contains(vs: VisitedSet, keys: jax.Array) -> jax.Array:
    """Membership test (negative keys are never members).  Works on any
    key shape; vectorised linear probing with early exit."""
    if isinstance(vs, DenseVisited):
        n = vs.bits.shape[0]
        return vs.bits[jnp.clip(keys, 0, n - 1)] & (keys >= 0) & (keys < n)

    size = vs.keys.shape[0]
    h = _hash(keys, size)
    found0 = jnp.zeros(jnp.shape(keys), bool)
    open0 = keys >= 0

    def cond(c):
        j, _, open_ = c
        return (j < size) & open_.any()

    def body(c):
        j, found, open_ = c
        slot = (h + j) & (size - 1)
        v = vs.keys[slot]
        found = found | (open_ & (v == keys))
        open_ = open_ & (v >= 0) & (v != keys)
        return j + 1, found, open_

    _, found, _ = lax.while_loop(cond, body,
                                 (jnp.int32(0), found0, open0))
    return found


def add(vs: VisitedSet, keys: jax.Array, mask: jax.Array) -> VisitedSet:
    """Insert ``keys[mask]`` (idempotent — present keys are no-ops).

    Hash sets probe until the key, an empty slot, or a full table; a full
    table drops the insert and bumps ``overflow`` (saturation — the caller
    may re-expand the vertex later, re-charging I/O only).
    """
    if isinstance(vs, DenseVisited):
        n = vs.bits.shape[0]
        ok = mask & (keys >= 0) & (keys < n)
        idx = jnp.where(ok, keys, n)               # out of bounds = dropped
        return DenseVisited(bits=vs.bits.at[idx].set(True))

    size = vs.keys.shape[0]
    flat_k = jnp.ravel(keys)
    flat_m = jnp.ravel(mask)

    def step(carry, i):
        table, count, overflow = carry
        k = flat_k[i]
        h = _hash(k, size)

        def insert(args):
            table, count, overflow = args
            # state: 0 = probing, 1 = found, 2 = empty slot claimed
            def cond(c):
                j, state, _ = c
                return (state == 0) & (j < size)

            def body(c):
                j, state, slot = c
                s = (h + j) & (size - 1)
                v = table[s]
                state = jnp.where(v == k, jnp.int32(1),
                                  jnp.where(v < 0, jnp.int32(2),
                                            jnp.int32(0)))
                return j + 1, state, jnp.where(state > 0, s, slot)

            _, state, slot = lax.while_loop(
                cond, body, (jnp.int32(0), jnp.int32(0), jnp.int32(0)))
            claimed = state == 2
            table = jnp.where(claimed, table.at[slot].set(k), table)
            count = count + claimed.astype(jnp.int32)
            overflow = overflow + (state == 0).astype(jnp.int32)
            return table, count, overflow

        carry = lax.cond(flat_m[i] & (k >= 0), insert, lambda a: a,
                         (table, count, overflow))
        return carry, None

    (table, count, overflow), _ = lax.scan(
        step, (vs.keys, vs.count, vs.overflow),
        jnp.arange(flat_k.shape[0]))
    return HashVisited(keys=table, count=count, overflow=overflow)


def overflow(vs: VisitedSet) -> jax.Array:
    """Saturation events so far (always 0 for the dense bitmap)."""
    if isinstance(vs, HashVisited):
        return vs.overflow
    return jnp.zeros((), jnp.int32)


def nbytes(vs: VisitedSet) -> int:
    """Per-query state footprint of this set (static — shape math only)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(vs))
