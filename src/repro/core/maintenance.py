"""Incremental maintenance: tombstone reclamation, edgelist repair + defrag.

Deletes only set a tombstone (paper §11, OdinANN's "deletion is benign"
argument): the slot is never reused, dead edges keep absorbing traversal
work, and out-of-place updates scatter edgelists across ever-fresher pages
— so a corpus under sustained delete+insert churn degrades on three axes
at once (capacity, recall, locality).  This module is the consolidation
path that undoes all three, FreshDiskANN-style but incremental:

① *repair* (``repair_block``): every live→dead edge is spliced away —
   the vacated slot is refilled with the dead vertex's symmetric-PQ-
   nearest live neighbor (a positional proxy for the removed edge), and
   the row's surviving edges are kept bit-identically, so connectivity
   routes *around* the hole and search results are preserved.  Runs in
   bounded blocks (``EngineSpec.maint_block``) so a step can interleave
   with foreground traffic.

①b *refine* (``refine_block``, engine-gated by
   ``EngineSpec.maint_refine``): vertices inserted since the last pass
   are re-seeked and RobustPrune(α)-rewired to build quality — the
   quality-restoring half of FreshDiskANN's StreamingConsolidate, which
   keeps a corpus whose membership turns over from drifting to
   unrefined-graph recall.

② *reclaim* (``reclaim_and_defrag``): after a full repair sweep no live
   edgelist references a dead vertex, so every tombstoned slot joins the
   free list that ``Engine._insert_inplace`` / ``insert_many`` draw from
   before falling back to fresh slots — inserts stop dropping once
   ``count`` reaches ``n_max``.  The tombstone bit stays set until the
   slot is actually reused (searches keep masking the stale record).

③ *defrag*: live edgelists are re-packed id-contiguously from page 0
   (:func:`repro.core.layout.defrag_edgelists`), restoring the
   decoupled layout's build-time page locality and resetting the bump
   page allocator; every page whose contents changed is invalidated in
   the host cache (``cache.invalidate_page``).

④ *entrance refresh* (``refresh_entrance``): surviving entrance members
   keep their wiring (static entrances top dead members' head-count back
   up; NAVIS's dynamic entrance re-grows through Algorithm 2 as inserts
   flow), holes are compacted near ``c_max``, and each member's edgelist
   page is priority-admitted into the frozen cache region
   (entrance-aware cache hint, §7).

All I/O is charged to ``IOCounters`` (``EngineState.ctr_maint``) so the
SSD model prices a pass exactly like foreground work: the repair sweep
reads each examined edge page once, repairs write through the layout's
normal update path (out-of-place relocation / in-place page rewrite),
and the defrag charges a stream read+write of every surviving page —
FreshDiskANN's documented consolidation overhead.  Maintenance reads
deliberately bypass the host cache (a full-file sweep would thrash the
frozen region the foreground searches depend on).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cache as cache_mod
from repro.core import graph as graph_mod
from repro.core import pq as pq_mod
from repro.core import search as search_mod
from repro.core.iomodel import (IOCounters, PAGE_BYTES, merge_counters,
                                sum_counters)
from repro.core.layout import (GraphStore, LayoutSpec, defrag_edgelists,
                               relocate_edgelists)

INF = jnp.float32(3.4e38)
REFINE_ALPHA = 1.2      # RobustPrune diversity, as the Vamana build pass


def _charge_list_writes(counters: IOCounters, spec: LayoutSpec,
                        n_lists, n_pages) -> IOCounters:
    """Charge writing ``n_lists`` edgelists over ``n_pages`` pages.  The
    packed layout drags each record's vector along (the Fig 4b co-write
    tax); decoupled pages carry edgelists only."""
    edge_b = (n_lists * spec.edgelist_bytes).astype(jnp.int64)
    vec_b = ((n_lists * spec.vector_bytes).astype(jnp.int64)
             if spec.kind == "packed" else jnp.int64(0))
    n_pages = n_pages.astype(jnp.int64)
    pad = n_pages * PAGE_BYTES - edge_b - vec_b
    return dataclasses.replace(
        counters,
        write_requests=counters.write_requests + n_pages,
        edge_bytes_written=counters.edge_bytes_written + edge_b,
        wasted_vec_bytes_written=counters.wasted_vec_bytes_written + vec_b,
        pad_bytes_written=counters.pad_bytes_written + pad)


# ---------------------------------------------------------------------------
# ① Repair (one bounded block of the sweep)
# ---------------------------------------------------------------------------

def repair_block(store: GraphStore, codes: jax.Array, sym_tables: jax.Array,
                 tombstone: jax.Array, cache: cache_mod.CacheState,
                 counters: IOCounters, start: jax.Array, *,
                 spec: LayoutSpec, block: int):
    """Repair rows ``[start, start+block)``: every live row's surviving
    edges are kept bit-identically (they carry the RobustPrune(α)
    diversity — long-range shortcuts included — that makes the graph
    navigable; re-pruning them by plain nearest-distance measurably
    collapses recall), and each slot a tombstoned vertex vacated is
    spliced: refilled with the dead vertex's own symmetric-PQ-nearest
    live neighbor not already in the row.  The fill is ranked around the
    *dead* vertex, not the row owner, so the replacement edge is a
    positional proxy for the one removed — a route that used to pass
    v → dead → x survives as v → x′ with x′ ≈ dead, preserving the
    traversals the edge served (including long-range ones).  Rows
    without dead edges are untouched, so the sweep is idempotent and
    order-independent — dead rows are never rewritten during the sweep,
    which is what lets blocks run in any order and interleave with
    foreground ops.

    Charges one edge-page read per distinct page backing an examined row
    or a spliced dead neighbor, and the layout's normal write cost for
    each repaired edgelist.  Returns (store, cache, counters, n_repaired).
    """
    n_max = store.n_max
    r = store.r
    p_max = store.page_live.shape[0]
    rows = start.astype(jnp.int32) + jnp.arange(block, dtype=jnp.int32)
    safe_rows = jnp.minimum(rows, n_max - 1)
    in_range = rows < store.count
    row_live = in_range & ~tombstone[safe_rows]
    row_edges = store.edges[safe_rows]                        # [B, R]
    dead = (row_edges >= 0) & tombstone[jnp.maximum(row_edges, 0)] & \
        row_live[:, None]
    need = row_live & dead.any(axis=1)

    def fix(vid, row, dead_row):
        def fill_slot(cur, j):
            d_vertex = row[j]                 # the slot's dead occupant

            def do(cur):
                cand = store.edges[jnp.maximum(d_vertex, 0)]   # [R]
                ok = (cand >= 0) & ~tombstone[jnp.maximum(cand, 0)] & \
                    (cand != vid) & \
                    ~(cand[:, None] == cur[None, :]).any(axis=1)
                dd = jnp.where(ok, pq_mod.sym_distance(
                    sym_tables, codes[jnp.maximum(d_vertex, 0)],
                    codes[jnp.maximum(cand, 0)]), INF)
                best = jnp.argmin(dd)
                return cur.at[j].set(
                    jnp.where(dd[best] < INF, cand[best], -1))

            return lax.cond(dead_row[j], do, lambda c: c, cur), None

        start_row = jnp.where(dead_row, -1, row)
        out, _ = lax.scan(fill_slot, start_row, jnp.arange(r))
        return out

    fixed = jax.vmap(fix)(safe_rows, row_edges, dead)          # [B, R]
    scatter = jnp.where(need, rows, n_max)                     # OOB dropped
    edges = store.edges.at[scatter].set(fixed)
    degree = store.degree.at[scatter].set(
        (fixed >= 0).sum(axis=1).astype(store.degree.dtype))
    store = dataclasses.replace(store, edges=edges, degree=degree)

    # -- read charging: distinct pages behind examined rows + splice srcs
    touched = jnp.zeros((p_max,), bool)
    row_pages = store.edge_page[safe_rows]
    touched = touched.at[jnp.where(row_live & (row_pages >= 0), row_pages,
                                   p_max)].set(True)
    dead_flat = jnp.where(dead, row_edges, -1).reshape(-1)
    dpages = store.edge_page[jnp.maximum(dead_flat, 0)]
    touched = touched.at[jnp.where((dead_flat >= 0) & (dpages >= 0),
                                   dpages, p_max)].set(True)
    counters = search_mod._charge_page_read(
        counters, spec, is_edge_page=True,
        n=touched.sum().astype(jnp.int64))

    # -- write charging: repaired rows through the layout's update path
    n_mod = need.sum()
    if spec.kind == "decoupled":
        moved_ids = jnp.where(need, rows, -1)
        old_pages = jnp.where(need, row_pages, -1)
        store, pages_written = relocate_edgelists(store, moved_ids, need,
                                                  spec)
        counters = _charge_list_writes(counters, spec, n_mod, pages_written)

        # §8.2 eviction hints for fully-invalidated old pages
        def hint(cache, i):
            pg = old_pages[i]
            dead_pg = (pg >= 0) & (store.page_live[jnp.maximum(pg, 0)] <= 0)
            return lax.cond(dead_pg,
                            lambda c: cache_mod.invalidate_page(c, pg),
                            lambda c: c, cache), None

        cache, _ = lax.scan(hint, cache, jnp.arange(block))
    else:
        pages = (n_mod * spec.packed_pages_per_vertex).astype(jnp.int64)
        counters = _charge_list_writes(counters, spec, n_mod, pages)
    return store, cache, counters, n_mod


# ---------------------------------------------------------------------------
# ①b Refine (quality restoration for churn-inserted vertices)
# ---------------------------------------------------------------------------

def refine_block(store: GraphStore, codes: jax.Array, codebooks: jax.Array,
                 sym_tables: jax.Array, tombstone: jax.Array,
                 cache: cache_mod.CacheState, counters: IOCounters,
                 vids: jax.Array, valid: jax.Array, entries: jax.Array, *,
                 spec: LayoutSpec, e_pos: int, beam_width: int,
                 max_hops: int, visited: str):
    """Re-wire a block of churn-inserted ("young") vertices to build
    quality: re-seek each on the current graph, RobustPrune(α) its pool ∪
    current edges by exact distance, replace the edgelist, and re-add
    reciprocal links (replace-worst-by-exact if closer).

    The runtime insert path wires by PQ-ranked nearest neighbors — good
    enough to be searchable, but without the α-diversity pass the Vamana
    build runs, so a corpus whose membership turns over under churn
    drifts toward unrefined-graph recall.  Re-refining what changed since
    the last pass anchors steady-state quality at build grade — this is
    the quality-restoring half of FreshDiskANN's StreamingConsolidate,
    and it is priced accordingly: each refine charges its full traversal,
    one exact-vector read per surviving candidate, and the layout's write
    cost for every rewritten edgelist.

    Returns (store, counters, n_refined).
    """
    codec = pq_mod.PQCodec(codebooks)
    n_max = store.n_max
    r = store.r
    safe_v = jnp.maximum(vids, 0)

    def seek(vid, ok):
        v = store.vectors[jnp.maximum(vid, 0)]
        lut = pq_mod.adc_lut(codec, v)
        res = search_mod.disk_traverse(
            store, spec, lut, codes, cache, IOCounters.zeros(), entries,
            pool_size=e_pos, beam_width=beam_width, max_hops=max_hops,
            frozen_cache=True, visited=visited)
        cand = jnp.concatenate([res.pool_ids, store.edges[
            jnp.maximum(vid, 0)]])
        safe = jnp.maximum(cand, 0)
        keep = (cand >= 0) & (cand != vid) & ~tombstone[safe]
        # sort-based dedupe (first occurrence wins)
        imax = jnp.iinfo(jnp.int32).max
        key = jnp.where(keep, cand, imax)
        si = jnp.argsort(key)
        sk = key[si]
        first = jnp.concatenate([jnp.ones((1,), bool),
                                 sk[1:] != sk[:-1]])
        keep &= jnp.zeros_like(keep).at[si].set(first)
        d = jnp.where(keep, pq_mod.exact_l2(v, store.vectors[safe]), INF)
        newr = graph_mod.robust_prune(v, jnp.where(keep, cand, -1), d,
                                      store.vectors, alpha=REFINE_ALPHA,
                                      r=r)
        # exact distances read the candidates' vectors from the slow tier
        ctr = res.counters
        n_cand = keep.sum()
        vp = spec.vector_pages_per_read
        if spec.kind == "decoupled":
            ctr = dataclasses.replace(
                ctr,
                read_requests=ctr.read_requests + n_cand * vp,
                useful_vec_bytes_read=ctr.useful_vec_bytes_read +
                n_cand * spec.vector_bytes,
                pad_bytes_read=ctr.pad_bytes_read +
                n_cand * (vp * PAGE_BYTES - spec.vector_bytes))
        # (packed: the traversal's edge pages already dragged vectors in)
        ctr = jax.tree.map(lambda x: jnp.where(ok, x, jnp.zeros_like(x)),
                           ctr)
        return jnp.where(ok, newr, store.edges[jnp.maximum(vid, 0)]), ctr

    new_rows, ctrs = jax.vmap(seek)(vids, valid)
    counters = merge_counters(counters, sum_counters(ctrs))

    # serial application: replace each edgelist, wire reciprocals by
    # exact distance (skip if already present), relocate modified rows
    b = vids.shape[0]

    def apply(carry, i):
        store, counters = carry
        vid, ok = vids[i], valid[i]

        def do(args):
            store, counters = args
            newr = new_rows[i]
            edges = store.edges.at[vid].set(newr)
            degree = store.degree.at[vid].set(
                (newr >= 0).sum().astype(store.degree.dtype))

            def wire(carry, j):
                edges, degree, modified = carry
                p = newr[j]

                def wire_one(args):
                    edges, degree, modified = args
                    row = edges[p]
                    present = (row == vid).any()
                    occupied = row >= 0
                    free = jnp.argmin(occupied)
                    has_free = ~occupied.all()
                    pvec = store.vectors[p]
                    d_row = jnp.where(occupied, pq_mod.exact_l2(
                        pvec, store.vectors[jnp.maximum(row, 0)]), -INF)
                    worst = jnp.argmax(d_row)
                    d_v = jnp.sum((pvec - store.vectors[vid]) ** 2)
                    tgt = jnp.where(has_free, free, worst)
                    write = (has_free | (d_v < d_row[worst])) & ~present
                    new_row = jnp.where(write, row.at[tgt].set(vid), row)
                    new_deg = jnp.where(write & has_free, degree[p] + 1,
                                        degree[p])
                    return (edges.at[p].set(new_row),
                            degree.at[p].set(new_deg),
                            modified.at[j].set(write))

                return lax.cond((p >= 0) & (p != vid), wire_one,
                                lambda a: a, (edges, degree, modified)), \
                    None

            modified0 = jnp.zeros((r,), bool)
            (edges, degree, modified), _ = lax.scan(
                wire, (edges, degree, modified0), jnp.arange(r))
            store = dataclasses.replace(store, edges=edges, degree=degree)

            n_mod = modified.sum() + 1                 # + vid's own row
            if spec.kind == "decoupled":
                moved = jnp.concatenate([vid[None].astype(jnp.int32),
                                         jnp.where(modified, newr, -1)])
                mvalid = moved >= 0
                store, pages = relocate_edgelists(store, moved, mvalid,
                                                  spec)
                counters = _charge_list_writes(counters, spec, n_mod,
                                               pages)
            else:
                pages = (n_mod * spec.packed_pages_per_vertex).astype(
                    jnp.int64)
                counters = _charge_list_writes(counters, spec, n_mod,
                                               pages)
            return store, counters

        carry = lax.cond(ok & (vid >= 0), do, lambda a: a,
                         (store, counters))
        return carry, None

    (store, counters), _ = lax.scan(apply, (store, counters),
                                    jnp.arange(b))
    return store, counters, valid.sum()


# ---------------------------------------------------------------------------
# ② + ③ Reclaim + defrag (cycle finalization)
# ---------------------------------------------------------------------------

def reclaim_and_defrag(store: GraphStore, tombstone: jax.Array,
                       free_list: jax.Array, free_count: jax.Array,
                       free_mask: jax.Array, cache: cache_mod.CacheState,
                       counters: IOCounters, *, spec: LayoutSpec):
    """Finalize a maintenance cycle after the repair sweep.

    Reclaims every tombstoned slot that no live edgelist references into
    the free list (post-sweep that is all of them; the reference check is
    a safety net for slots deleted *during* an interleaved sweep), clears
    the reclaimed rows, re-packs the survivors' edgelists contiguously
    from page 0, and invalidates every cache-resident page whose contents
    moved.  Charges the defrag's stream read+write.  Returns
    (store, free_list, free_count, free_mask, cache, counters,
    n_reclaimed).
    """
    n_max = store.n_max
    p_max = store.page_live.shape[0]
    idx = jnp.arange(n_max, dtype=jnp.int32)
    in_prefix = idx < store.count
    row_live = in_prefix & ~tombstone

    tgt = jnp.where(row_live[:, None] & (store.edges >= 0), store.edges,
                    n_max)
    referenced = jnp.zeros((n_max,), bool).at[tgt.reshape(-1)].set(True)
    new_free = in_prefix & tombstone & ~free_mask & ~referenced

    pos = jnp.where(new_free,
                    free_count + jnp.cumsum(new_free.astype(jnp.int32)) - 1,
                    n_max)                                    # OOB dropped
    free_list = free_list.at[pos].set(idx)
    free_count = free_count + new_free.sum().astype(jnp.int32)
    free_mask = free_mask | new_free

    # reclaimed rows hold no graph state until an insert reuses the slot
    edges = jnp.where(free_mask[:, None], -1, store.edges)
    degree = jnp.where(free_mask, 0, store.degree)
    store = dataclasses.replace(store, edges=edges, degree=degree)

    # defrag: everything not reclaimed keeps a (fresh, contiguous) page
    holders = in_prefix & ~free_mask
    n_hold = holders.sum()
    pre_pages = jnp.zeros((p_max,), bool).at[
        jnp.where(holders & (store.edge_page >= 0), store.edge_page,
                  p_max)].set(True)
    store, changed, n_pages = defrag_edgelists(store, holders, spec)
    counters = search_mod._charge_page_read(
        counters, spec, is_edge_page=True,
        n=pre_pages.sum().astype(jnp.int64))                 # stream read
    counters = _charge_list_writes(counters, spec, n_hold, n_pages)

    # drop every cache-resident page whose contents moved, plus any page
    # the rebuilt map left without a single live edgelist (repair may
    # have drained a page without tripping its own fully-dead hint)
    drop = changed | (store.page_live <= 0)

    def inv(cache, p):
        return lax.cond(drop[p],
                        lambda c: cache_mod.invalidate_page(c, p),
                        lambda c: c, cache), None

    cache, _ = lax.scan(inv, cache, jnp.arange(p_max, dtype=jnp.int32))
    return (store, free_list, free_count, free_mask, cache, counters,
            new_free.sum())


# ---------------------------------------------------------------------------
# ④ Entrance-refresh helpers (engine orchestrates the rebuild itself)
# ---------------------------------------------------------------------------

def refresh_entrance(key: jax.Array, codes: jax.Array,
                     sym_tables: jax.Array, old_ent, tombstone,
                     live_ids, *, sample_frac: float, r_ent: int,
                     n_max: int, top_up: bool = True):
    """Refresh the entrance graph over the post-compaction live set,
    *incrementally*: surviving members and their wiring are untouched
    (their placement has been serving traversals; a from-scratch resample
    at the ~1% sample size has brutal seed-coverage variance, and keeping
    the structure is what preserves search results across a pass).

    ``top_up=True`` (static entrances — consolidation is their only
    refresh): the head-count dead members vacated is topped back up with
    fresh live samples via :func:`repro.core.entrance.add_member`.

    ``top_up=False`` (NAVIS's dynamic entrance): the paper's own
    Algorithm 2 re-grows coverage as inserts flow — its trigger compares
    *live* membership against the target fraction, so scrubbed members
    re-open promotion headroom — and consolidation leaves a
    still-serving structure bit-identical.

    Either way, when the slot high-water mark ``count`` nears ``c_max``
    (delete slots are never recycled in place, so sustained churn leaks
    them), the holes are compacted with a full survivor re-link
    (:func:`repro.core.entrance.link_members`).

    Host-orchestrated (member selection needs concrete counts); returns
    an :class:`EntranceGraph`.
    """
    import numpy as np
    from repro.core import entrance as ent_mod
    c_max = old_ent.c_max
    n_live = int(live_ids.shape[0])
    target = max(min(int(n_live * sample_frac), c_max), min(n_live, 2))

    old = np.asarray(old_ent.ids)
    old = old[old >= 0]
    survivors = old[~np.asarray(tombstone)[old]][:target]
    need = (target - len(survivors)) if top_up else 0
    if need > 0:
        pool = np.setdiff1d(np.asarray(live_ids), survivors)
        pick = jax.random.choice(key, pool.shape[0],
                                 (min(need, pool.shape[0]),),
                                 replace=False)
        fresh = pool[np.asarray(pick)]
    else:
        fresh = np.zeros((0,), np.int32)
    members = np.concatenate([survivors, fresh]).astype(np.int32)
    if int(old_ent.count) + len(fresh) + r_ent > c_max and \
            len(members) >= 2:                            # compact holes
        return ent_mod.link_members(
            jnp.asarray(members, jnp.int32), codes, sym_tables,
            c_max=c_max, r_ent=r_ent, n_max=n_max)
    ent = old_ent
    for vid in fresh:
        ent = ent_mod.add_member(ent, jnp.asarray(vid, jnp.int32), codes,
                                 sym_tables)
    return ent


def admit_entrance_pages(cache: cache_mod.CacheState, store: GraphStore,
                         ent) -> cache_mod.CacheState:
    """Priority-admit every live entrance member's edgelist page into the
    frozen cache region — after a refresh the new members seed every
    traversal, so their pages are the hottest in the system (§7's
    entrance-aware cache, lightweight version).  No-op for non-NAVIS
    cache policies (``priority_admit`` gates itself)."""
    def step(cache, i):
        vid = ent.ids[i]
        page = store.edge_page[jnp.maximum(vid, 0)]
        return lax.cond((vid >= 0) & (page >= 0),
                        lambda c: cache_mod.priority_admit(c, page),
                        lambda c: c, cache), None

    cache, _ = lax.scan(step, cache, jnp.arange(ent.c_max))
    return cache


def refresh_default_entries(key: jax.Array, vectors: jax.Array,
                            live_ids: jax.Array, n_entry: int) -> jax.Array:
    """Fallback entry points over the post-compaction live set: the live
    medoid first (mirroring the build), then random live picks.  The old
    defaults may be tombstoned — a traversal seeded there burns hops in
    a repaired-away region."""
    live_vecs = vectors[live_ids]
    c = live_vecs.mean(axis=0)
    med = live_ids[jnp.argmin(jnp.sum((live_vecs - c) ** 2, axis=1))]
    rest = live_ids[jax.random.randint(key, (n_entry - 1,), 0,
                                       live_ids.shape[0])]
    return jnp.concatenate([med[None], rest]).astype(jnp.int32)
