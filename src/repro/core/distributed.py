"""Pod-scale GVS: the engine sharded over the production mesh.

The database is range-sharded over every mesh axis (16×16 single pod =
256 shards; 2×16×16 = 512): each device owns ``n_per`` vertices with a
private proximity graph, entrance graph, cache and PQ codes — exactly the
deployment the paper's single-node engine scales out to (queries fan out,
per-shard top-k merge; inserts route to their owning shard by id hash).

* ``sharded_search``: queries are replicated to every shard (one
  all-gather-free broadcast — they arrive replicated), each shard runs its
  local beam search + rerank, and the global top-k is reduced with one
  ``all_gather`` of the per-shard (k dists, k ids) pools — k·(4+4) bytes
  per shard per query, tiny next to the per-shard traversal.
* ``sharded_insert``: the host router buckets new vectors by
  ``hash(id) % n_shards``; every shard runs its bucket (padded to the
  same length — shape-static SPMD) through the ``insert_many`` fan-out:
  concurrent position seeks on the shard snapshot, serialized
  conflict-aware commits.  No cross-shard edges: the shards are
  independent graphs, which is how multi-segment deployments (Starling,
  Qdrant) scale writes.

``dryrun()`` lowers + compiles both ops on the production meshes with
ShapeDtypeStructs (no allocation) — the GVS counterpart of
launch/dryrun.py, feeding §Roofline's paper-technique row.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.core import engine as engine_mod
from repro.core import pq as pq_mod

INF = jnp.float32(3.4e38)


def db_axes(mesh) -> tuple[str, ...]:
    """Every mesh axis shards the database (GVS has no tensor parallelism)."""
    return tuple(mesh.axis_names)


def n_shards(mesh) -> int:
    return int(mesh.devices.size)


# ---------------------------------------------------------------------------
# Host-side build + routing
# ---------------------------------------------------------------------------

def build_sharded_state(engine: engine_mod.Engine, key: jax.Array,
                        vectors: jax.Array, n_shards_: int):
    """Range-shard ``vectors`` and build one engine state per shard,
    stacked on a leading shard axis (host-side, CPU-scale helper).

    One PQ codec is trained on the *global* corpus and installed before
    the per-shard builds — per-shard codecs would make PQ distances (and
    the global top-k merge) incomparable across shards."""
    n = vectors.shape[0]
    per = n // n_shards_
    sample = vectors[jax.random.choice(
        key, n, (min(n, 4096),), replace=False)]
    engine.codec = pq_mod.train_pq(key, sample, engine.spec.pq_m)
    states = []
    for s in range(n_shards_):
        st = engine.build(jax.random.fold_in(key, s),
                          vectors[s * per:(s + 1) * per])
        states.append(st)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def route_inserts(vectors: jax.Array, ids: jax.Array, n_shards_: int,
                  bucket: int):
    """Bucket vectors by owner shard (hash = id % shards), padding every
    bucket to ``bucket`` entries.  Returns ([S, bucket, D], [S, bucket] valid).
    """
    import numpy as np
    v = np.asarray(vectors)
    idn = np.asarray(ids)
    out = np.zeros((n_shards_, bucket, v.shape[1]), np.float32)
    valid = np.zeros((n_shards_, bucket), bool)
    fill = np.zeros(n_shards_, np.int32)
    for vec, i in zip(v, idn):
        s = int(i) % n_shards_
        if fill[s] < bucket:
            out[s, fill[s]] = vec
            valid[s, fill[s]] = True
            fill[s] += 1
    return jnp.asarray(out), jnp.asarray(valid)


# ---------------------------------------------------------------------------
# SPMD ops
# ---------------------------------------------------------------------------

def make_sharded_search(engine: engine_mod.Engine, mesh, *,
                        n_per: int, n_queries: int, parallel: bool = True):
    """Jitted (stacked_state, queries [Q, D]) -> (ids [Q, k], dists [Q, k],
    stacked_state).  Global ids = shard_index * n_per + local id.

    ``parallel=True`` (default) runs each shard's query batch through the
    vmapped ``search_many`` fan-out — the per-shard analogue of the
    paper's concurrent search threads — instead of the serial
    state-threading scan; results are identical, the shard just stops
    serialising its own readers.
    """
    axes = db_axes(mesh)
    k = engine.spec.k
    search = engine._search_many if parallel else engine._search_batch

    def local(state_stk, queries):
        state = jax.tree.map(lambda x: x[0], state_stk)
        ids, dists, _, state = search(state, queries)
        # globalise ids: flatten the multi-axis shard index
        flat = jnp.zeros((), jnp.int32)
        for ax in axes:
            flat = flat * axis_size(ax) + jax.lax.axis_index(ax)
        gids = jnp.where(ids >= 0, ids + flat * n_per, -1)
        # merge: gather every shard's (dist, id) pool, reduce locally
        all_d = lax.all_gather(jnp.where(ids >= 0, dists, INF),
                               axes, tiled=False)          # [S.., Q, k]
        all_i = lax.all_gather(gids, axes, tiled=False)
        all_d = all_d.reshape(-1, queries.shape[0], k)
        all_i = all_i.reshape(-1, queries.shape[0], k)
        neg, sel = lax.top_k(-all_d.transpose(1, 0, 2).reshape(
            queries.shape[0], -1), k)
        gi = jnp.take_along_axis(
            all_i.transpose(1, 0, 2).reshape(queries.shape[0], -1),
            sel, axis=1)
        out_i = jnp.where(neg > -INF, gi, -1)
        return out_i, -neg, jax.tree.map(lambda x: x[None], state)

    spec_state = P(axes)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(spec_state, P()),              # queries replicated
        out_specs=(P(), P(), spec_state),
        check_vma=False)
    return jax.jit(fn)


def make_sharded_insert(engine: engine_mod.Engine, mesh, *, bucket: int,
                        parallel: bool = True):
    """Jitted (stacked_state, routed [S, bucket, D], valid [S, bucket]) ->
    stacked_state.  Each shard inserts only its own bucket.

    ``parallel=True`` (default) routes the bucket through the shard-local
    ``insert_many`` fan-out — every shard position-seeks its whole bucket
    concurrently against its own snapshot and serialises only the
    conflict-aware commits, the write-side analogue of the parallel
    sharded search.  Padding lanes ride the ``valid`` mask.  Buffered
    engines fall back to the sequential scan (no seek to parallelise).
    """
    axes = db_axes(mesh)
    fan_out = parallel and engine.spec.update_path != "buffered"

    def local(state_stk, routed, valid):
        state = jax.tree.map(lambda x: x[0], state_stk)
        vecs, ok = routed[0], valid[0]

        if fan_out:
            _, state = engine._insert_many(state, vecs, valid=ok)
            return jax.tree.map(lambda x: x[None], state)

        def step(state, xs):
            v, keep = xs

            def do(state):
                _, state, _ = engine._insert(state, v)
                return state

            return lax.cond(keep, do, lambda s: s, state), None

        state, _ = lax.scan(step, state, (vecs, ok))
        return jax.tree.map(lambda x: x[None], state)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axes), P(axes), P(axes)),
        out_specs=P(axes),
        check_vma=False)
    return jax.jit(fn, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# Dry-run (production mesh, ShapeDtypeStructs only)
# ---------------------------------------------------------------------------

def state_shapes(engine: engine_mod.Engine, n_shards_: int, n_per: int):
    """ShapeDtypeStruct pytree of a stacked sharded state (no allocation)."""
    spec = engine.spec.with_(n_max=n_per)
    eng = engine_mod.Engine(spec)
    # mirror Engine.build's shapes without computing anything
    from repro.core import cache as cache_mod
    from repro.core import entrance as ent_mod
    from repro.core.iomodel import IOCounters
    from repro.core.layout import empty_store

    def shaped(x):
        return jax.ShapeDtypeStruct((n_shards_,) + x.shape, x.dtype)

    store = empty_store(n_per, spec.dim, spec.r)
    c_max = max(int(spec.ent_frac * n_per * 2), 64)
    ent = ent_mod.empty_entrance(c_max, spec.r_ent, n_per)
    cache = cache_mod.init_cache(store.page_live.shape[0],
                                 spec.cache_capacity_pages,
                                 spec.cache_policy, jax.random.PRNGKey(0))
    state = engine_mod.EngineState(
        store=store,
        codes=jnp.zeros((n_per, spec.pq_m), jnp.uint8),
        ent=ent, cache=cache,
        tombstone=jnp.zeros((n_per,), bool),
        default_entries=jnp.zeros((spec.n_entry,), jnp.int32),
        ctr_search=IOCounters.zeros(), ctr_insert=IOCounters.zeros(),
        buf_vecs=jnp.zeros((spec.buffer_max, spec.dim), jnp.float32),
        buf_count=jnp.zeros((), jnp.int32),
        n_deleted=jnp.zeros((), jnp.int32),
        free_list=jnp.full((n_per,), -1, jnp.int32),
        free_count=jnp.zeros((), jnp.int32),
        free_mask=jnp.zeros((n_per,), bool),
        maint_cursor=jnp.zeros((), jnp.int32),
        young_mask=jnp.zeros((n_per,), bool),
        ctr_maint=IOCounters.zeros())
    return jax.tree.map(shaped, state)


def dryrun(engine: engine_mod.Engine, mesh, *, n_per: int = 65_536,
           n_queries: int = 64, bucket: int = 8):
    """Lower + compile sharded search and insert on ``mesh``.

    The engine must have a codec installed (build a tiny CPU instance or
    call :meth:`engine.Engine.build` on a small sample first); the codec
    arrays are compile-time constants, so a smoke-scale codec is fine.
    Returns {op: (lowered, compiled)}.
    """
    S = n_shards(mesh)
    sstate = state_shapes(engine, S, n_per)
    q = jax.ShapeDtypeStruct((n_queries, engine.spec.dim), jnp.float32)
    routed = jax.ShapeDtypeStruct((S, bucket, engine.spec.dim), jnp.float32)
    valid = jax.ShapeDtypeStruct((S, bucket), jnp.bool_)

    out = {}
    with mesh:
        search_fn = make_sharded_search(engine, mesh, n_per=n_per,
                                        n_queries=n_queries)
        lowered = search_fn.lower(sstate, q)
        out["search"] = (lowered, lowered.compile())
        insert_fn = make_sharded_insert(engine, mesh, bucket=bucket)
        lowered = insert_fn.lower(sstate, routed, valid)
        out["insert"] = (lowered, lowered.compile())
    return out
