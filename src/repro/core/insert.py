"""In-place insertion: ① position seeking → ② structural update.

Position seeking is a full graph traversal with a large explored pool
(|E_pos| ≫ |E_search|) whose only job is to surface ~R adequate neighbors
for the new vertex — the paper's diagnosis is that this step dominates
update cost.  The traversal itself reuses :func:`search.disk_traverse`;
the rerank is either the packed-layout full rerank or CASR.

The structural update wires the new vertex to its selected neighbors,
adds reciprocal edges (pruning the farthest edge by symmetric-PQ distance
when a neighbor is already at max degree R), and charges the layout's
write costs:

* packed:   (1 + #modified neighbors) full pages — every neighbor's vector
            is rewritten although the update never touched it (Fig. 4b).
* decoupled: the modified edgelists are gathered out-of-place onto fresh
            edge pages (⌈M/edgelists_per_page⌉ page writes) plus exactly
            one vector write for the new vertex.

RMW reads are free here: the wired neighbors come from the converged
explored pool, so their edge pages were read during this very insert's
traversal and still sit in the insert's RMW staging buffer (§8.2) — the
paper charges the same way.  The one exception is a *wave* commit
(``Engine.insert_many``): its staging buffer holds the pre-wave snapshot,
so pages dirtied by earlier commits in the same wave are stale and the
re-read is charged (:func:`charge_rmw_rereads`).

The module is split so the engine can overlap the read-heavy phase across
an update wave: :func:`position_seek` (pure, vmap-safe, frozen-cache
capable) produces the neighbor pool; :func:`commit_insert` /
:func:`structural_update` applies it; :func:`insert_vertex` is the
sequential composition of the two.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cache as cache_mod
from repro.core import casr as casr_mod
from repro.core import pq as pq_mod
from repro.core import search as search_mod
from repro.core.iomodel import IOCounters, PAGE_BYTES
from repro.core.layout import GraphStore, LayoutSpec, relocate_edgelists

INF = jnp.float32(3.4e38)


# ---------------------------------------------------------------------------
# Neighbor selection (paper §5.2-5.3)
# ---------------------------------------------------------------------------

def select_neighbors(pool_ids: jax.Array, casr_res, r: int) -> jax.Array:
    """Order the pool for wiring: the CASR-loaded close portion ranked by
    exact distance first, then the unloaded remainder in PQ order (shortcut
    slots need diversity, not exactness).  Returns [r] ids (-1 padded)."""
    P = pool_ids.shape[0]
    valid = pool_ids >= 0
    arange = jnp.arange(P, dtype=jnp.float32)
    # loaded → exact distance;  unloaded-valid → big + PQ rank (stable);
    # invalid → +inf.  exact distances are always ≪ 1e30.
    key = jnp.where(casr_res.loaded & valid, casr_res.exact_d,
                    jnp.where(valid, 1e30 + arange, INF))
    order = jnp.argsort(key)
    return jnp.where(valid[order], pool_ids[order], -1)[:r]


def full_pool_neighbors(pool_ids: jax.Array, r: int) -> jax.Array:
    """Baseline neighbor selection: pool already exact-reranked — take R."""
    return pool_ids[:r]


# ---------------------------------------------------------------------------
# Structural update
# ---------------------------------------------------------------------------

class StructuralResult(NamedTuple):
    store: GraphStore
    cache: cache_mod.CacheState
    counters: IOCounters
    n_wired: jax.Array      # reciprocal edges actually added
    modified: jax.Array     # [r] bool — which nbr edgelists were rewritten


def _wire_reciprocal(store: GraphStore, nbrs: jax.Array, new_id: jax.Array,
                     codes: jax.Array, sym_tables: jax.Array):
    """Add new_id into each neighbor's edgelist (prune farthest if full).

    Returns (edges, degree, modified[r] bool).
    """
    r_slots = nbrs.shape[0]

    def wire(carry, i):
        edges, degree = carry
        p = nbrs[i]

        def do(args):
            edges, degree = args
            row = edges[p]
            occupied = row >= 0
            free = jnp.argmin(occupied)                  # first empty slot
            has_free = ~occupied.all()
            p_code = codes[p]
            row_codes = codes[jnp.maximum(row, 0)]
            d_row = jnp.where(
                occupied,
                pq_mod.sym_distance(sym_tables, p_code, row_codes), -INF)
            worst = jnp.argmax(d_row)
            d_new = pq_mod.sym_distance(sym_tables, p_code,
                                        codes[new_id][None])[0]
            tgt = jnp.where(has_free, free, worst)
            write = has_free | (d_new < d_row[worst])
            new_row = jnp.where(write, row.at[tgt].set(new_id), row)
            new_deg = jnp.where(write & has_free, degree[p] + 1, degree[p])
            return (edges.at[p].set(new_row),
                    degree.at[p].set(new_deg)), write

        def skip(args):
            return args, jnp.bool_(False)

        dup = jnp.any((nbrs == p) & (jnp.arange(r_slots) < i))
        (edges, degree), modified = lax.cond(
            (p >= 0) & (p != new_id) & ~dup, do, skip, (edges, degree))
        return (edges, degree), modified

    (edges, degree), modified = lax.scan(
        wire, (store.edges, store.degree), jnp.arange(r_slots))
    return edges, degree, modified


def _charge_writes(counters: IOCounters, spec: LayoutSpec,
                   n_modified_nbrs: jax.Array,
                   edge_pages_written: jax.Array) -> IOCounters:
    """Write-side accounting for one insertion under either layout."""
    el = spec.edgelist_bytes
    vb = spec.vector_bytes
    if spec.kind == "packed":
        ppv = spec.packed_pages_per_vertex
        n_pages = (1 + n_modified_nbrs) * ppv
        edge_b = (1 + n_modified_nbrs) * el
        vec_b = jnp.int64(vb)                        # the new vertex (useful)
        wasted_b = (n_modified_nbrs * vb).astype(jnp.int64)  # co-written
        pad = (n_pages * PAGE_BYTES - edge_b - vec_b - wasted_b)
        return dataclasses.replace(
            counters,
            write_requests=counters.write_requests + n_pages.astype(jnp.int64),
            edge_bytes_written=counters.edge_bytes_written +
            edge_b.astype(jnp.int64),
            vec_bytes_written=counters.vec_bytes_written + vec_b,
            wasted_vec_bytes_written=counters.wasted_vec_bytes_written +
            wasted_b,
            pad_bytes_written=counters.pad_bytes_written +
            pad.astype(jnp.int64))
    # decoupled: out-of-place edge pages + exactly one vector write
    vec_pages = spec.vector_pages_per_read
    edge_b = ((1 + n_modified_nbrs) * el).astype(jnp.int64)
    edge_pad = edge_pages_written.astype(jnp.int64) * PAGE_BYTES - edge_b
    return dataclasses.replace(
        counters,
        write_requests=counters.write_requests +
        edge_pages_written.astype(jnp.int64) + vec_pages,
        edge_bytes_written=counters.edge_bytes_written + edge_b,
        vec_bytes_written=counters.vec_bytes_written + jnp.int64(vb),
        pad_bytes_written=counters.pad_bytes_written + edge_pad +
        jnp.int64(vec_pages * PAGE_BYTES - vb))


def structural_update(store: GraphStore, spec: LayoutSpec,
                      cache: cache_mod.CacheState, counters: IOCounters,
                      new_vec: jax.Array, nbrs: jax.Array,
                      codes: jax.Array, sym_tables: jax.Array,
                      new_id: jax.Array | None = None) -> StructuralResult:
    """② Commit a new vertex with neighbor list ``nbrs`` [R].

    ``new_id`` picks the slot: ``None`` (the default, and the only mode
    before the maintenance subsystem existed) appends at ``store.count``;
    an explicit id < count re-occupies a slot the maintenance pass
    reclaimed from a tombstoned vertex — ``count`` only advances when the
    slot extends the prefix, so reuse never inflates the live range.
    """
    new_id = (store.count if new_id is None else new_id).astype(jnp.int32)
    r = store.r

    # the new vertex's own record
    vectors = store.vectors.at[new_id].set(new_vec.astype(
        store.vectors.dtype))
    nbrs = jnp.where(nbrs == new_id, -1, nbrs)               # no self loops
    edges = store.edges.at[new_id].set(nbrs)
    degree = store.degree.at[new_id].set(
        (nbrs >= 0).sum().astype(store.degree.dtype))
    store = dataclasses.replace(store, vectors=vectors, edges=edges,
                                degree=degree)

    # reciprocal wiring + prune
    edges, degree, modified = _wire_reciprocal(store, nbrs, new_id, codes,
                                               sym_tables)
    store = dataclasses.replace(store, edges=edges, degree=degree,
                                count=jnp.maximum(store.count, new_id + 1))

    n_modified = modified.sum()
    if spec.kind == "packed":
        # in-place page rewrites; the new vertex gets a fresh page group
        edge_page = store.edge_page.at[new_id].set(store.next_page)
        page_live = store.page_live.at[store.next_page].add(1)
        store = dataclasses.replace(store, edge_page=edge_page,
                                    page_live=page_live,
                                    next_page=store.next_page + 1)
        counters = _charge_writes(counters, spec, n_modified,
                                  jnp.int32(0))
        return StructuralResult(store, cache, counters, n_modified, modified)

    # decoupled: gather new + modified edgelists onto fresh pages
    moved_ids = jnp.concatenate([jnp.array([new_id], jnp.int32),
                                 jnp.where(modified, nbrs, -1)])
    moved_valid = moved_ids >= 0
    old_pages = jnp.where(moved_valid,
                          store.edge_page[jnp.maximum(moved_ids, 0)], -1)
    store, pages_written = relocate_edgelists(store, moved_ids, moved_valid,
                                              spec)
    counters = _charge_writes(counters, spec, n_modified, pages_written)

    # §8.2 eviction hints: any old edge page left with zero live slots
    def hint(cache, i):
        pg = old_pages[i]
        dead = (pg >= 0) & (store.page_live[jnp.maximum(pg, 0)] <= 0)
        return lax.cond(dead,
                        lambda c: cache_mod.invalidate_page(c, pg),
                        lambda c: c, cache), None

    cache, _ = lax.scan(hint, cache, jnp.arange(moved_ids.shape[0]))
    return StructuralResult(store, cache, counters, n_modified, modified)


# ---------------------------------------------------------------------------
# Conflict-aware wave commits (batch-parallel insert fan-out)
# ---------------------------------------------------------------------------
#
# ``insert_many`` runs position seeking for a whole insert wave against one
# frozen snapshot of the engine state (phase ①, vmapped), then commits the
# structural updates serially (phase ②, lax.scan).  A commit late in the
# wave sees a graph already mutated by the earlier commits, so its
# snapshot-derived neighbor picks must be re-validated, and any neighbor
# edge page dirtied by a prior commit must be re-read before the RMW —
# the snapshot copy its own traversal read is stale.  These two helpers
# are that conflict handling; both are pure and scan-friendly.

def revalidate_neighbors(nbrs: jax.Array, new_id: jax.Array,
                         new_code: jax.Array, codes: jax.Array,
                         sym_tables: jax.Array,
                         tombstone: jax.Array) -> jax.Array:
    """Re-check a snapshot-selected neighbor list [r] at commit time.

    Drops self-references, duplicates and now-tombstoned picks, then
    re-prunes the survivors by symmetric-PQ distance to the new vertex
    — measured against ``new_code``, which the wave commit holds in hand
    (codes live in host memory — re-validation costs no storage I/O).
    Returns [r] ids, -1 padded at the tail.
    """
    r = nbrs.shape[0]
    safe = jnp.maximum(nbrs, 0)
    arange = jnp.arange(r)
    dup = ((nbrs[:, None] == nbrs[None, :]) & (nbrs[None, :] >= 0) &
           (arange[None, :] < arange[:, None])).any(axis=1)
    valid = (nbrs >= 0) & (nbrs != new_id) & ~tombstone[safe] & ~dup
    d = pq_mod.sym_distance(sym_tables, new_code, codes[safe])
    order = jnp.argsort(jnp.where(valid, d, INF))
    return jnp.where(valid[order], nbrs[order], -1)


def charge_rmw_rereads(counters: IOCounters, spec: LayoutSpec,
                       store: GraphStore, nbrs: jax.Array,
                       dirty_pages: jax.Array
                       ) -> tuple[IOCounters, jax.Array]:
    """Charge the RMW re-reads a wave commit owes for conflicting pages.

    The sequential insert path gets RMW reads for free: the wired
    neighbors come from the converged explored pool, so their edge pages
    sit in the insert's own staging buffer.  In a wave, that buffer holds
    the *snapshot* version — if a prior commit in the same wave dirtied a
    neighbor's current edge page, the commit must re-read it, one page
    read per distinct dirty page.  Returns (counters, n_reread).
    """
    r = nbrs.shape[0]
    valid = nbrs >= 0
    pages = jnp.where(valid, store.edge_page[jnp.maximum(nbrs, 0)], -1)
    arange = jnp.arange(r)
    dup = ((pages[:, None] == pages[None, :]) & (pages[None, :] >= 0) &
           (arange[None, :] < arange[:, None])).any(axis=1)
    hit = valid & (pages >= 0) & dirty_pages[jnp.maximum(pages, 0)] & ~dup
    n = hit.sum()
    counters = search_mod._charge_page_read(counters, spec,
                                            is_edge_page=True, n=n)
    return counters, n


def mark_dirty_pages(dirty_pages: jax.Array, store: GraphStore,
                     new_id: jax.Array, nbrs: jax.Array,
                     modified: jax.Array) -> jax.Array:
    """Record the pages a commit wrote (post-commit ``store``): the new
    vertex's page and every rewritten/relocated neighbor edgelist's
    current page.  Later commits in the wave consult this map to charge
    their RMW re-reads."""
    touched = jnp.concatenate([new_id[None].astype(jnp.int32),
                               jnp.where(modified, nbrs, -1)])
    pages = store.edge_page[jnp.maximum(touched, 0)]
    idx = jnp.where((touched >= 0) & (pages >= 0), pages,
                    dirty_pages.shape[0])                 # OOB = dropped
    return dirty_pages.at[idx].set(True)


# ---------------------------------------------------------------------------
# Full insertion (position seek + rerank + wire)
# ---------------------------------------------------------------------------

class SeekResult(NamedTuple):
    """Phase-① output: everything a structural commit needs, plus the
    traversal's I/O evidence (trace / page_seen) for cache replay."""
    nbrs: jax.Array           # [R] selected neighbors (-1 padded)
    pool_ids: jax.Array       # E_pos (PQ-sorted, tombstone-masked)
    hops: jax.Array
    rerank_rounds: jax.Array
    cache: cache_mod.CacheState   # threaded (sequential) / snapshot (frozen)
    counters: IOCounters
    page_seen: jax.Array      # pages this seek's traversal touched
    trace: jax.Array | None = None    # frozen mode: charged page accesses
    trace_n: jax.Array | None = None


def position_seek(store: GraphStore, spec: LayoutSpec, codec: pq_mod.PQCodec,
                  codes: jax.Array, cache: cache_mod.CacheState,
                  counters: IOCounters, new_vec: jax.Array,
                  entry_ids: jax.Array, *, e_pos: int, k: int, s: int,
                  rerank: str = "casr", beam_width: int = 4,
                  max_hops: int = 512, tombstone: jax.Array | None = None,
                  page_seen: jax.Array | None = None,
                  frozen_cache: bool = False,
                  visited: str = "hash") -> SeekResult:
    """① Position seeking: traverse + rerank + neighbor selection, no
    structural mutation.  Pure in the engine state, so a whole insert wave
    runs concurrently under ``vmap`` with ``frozen_cache=True`` (each seek
    probes the cache snapshot and records its page-access trace, exactly
    like the search fan-out).  ``visited`` picks the traversal's visited
    sets — "hash" keeps per-seek state independent of the corpus, so an
    insert wave's memory is bounded by the frontier, not ``n_max``."""
    lut = pq_mod.adc_lut(codec, new_vec)
    res = search_mod.disk_traverse(
        store, spec, lut, codes, cache, counters, entry_ids,
        pool_size=e_pos, beam_width=beam_width, max_hops=max_hops,
        page_seen=page_seen, frozen_cache=frozen_cache, visited=visited)
    counters = res.counters
    cache = res.cache
    pool_ids = res.pool_ids
    if tombstone is not None:
        dead = (pool_ids >= 0) & tombstone[jnp.maximum(pool_ids, 0)]
        counters = dataclasses.replace(
            counters, tombstone_skips=counters.tombstone_skips +
            dead.sum().astype(jnp.int64))
        pool_ids = jnp.where(dead, -1, pool_ids)

    if rerank == "casr":
        cres = casr_mod.casr_rerank(store, spec, new_vec, pool_ids,
                                    counters, k=k, s=s)
        counters = cres.counters
        nbrs = select_neighbors(pool_ids, cres, store.r)
        rounds = cres.rerank_rounds
    else:
        ids, _, _, counters = search_mod.full_rerank(
            store, spec, new_vec, res._replace(pool_ids=pool_ids),
            counters, k=pool_ids.shape[0])
        nbrs = full_pool_neighbors(ids, store.r)
        rounds = jnp.int32(1)

    return SeekResult(nbrs=nbrs, pool_ids=pool_ids, hops=res.hops,
                      rerank_rounds=rounds, cache=cache, counters=counters,
                      page_seen=res.page_seen, trace=res.trace,
                      trace_n=res.trace_n)


# ② The structural commit for a precomputed neighbor pool is
# :func:`structural_update`; wave commits re-validate first.
commit_insert = structural_update


class InsertResult(NamedTuple):
    store: GraphStore
    cache: cache_mod.CacheState
    counters: IOCounters
    new_id: jax.Array
    pool_ids: jax.Array       # E_pos (PQ-sorted) — reused by NAVIS-update
    hops: jax.Array
    rerank_rounds: jax.Array
    page_seen: jax.Array      # pages this insert's traversal touched


def insert_vertex(store: GraphStore, spec: LayoutSpec, codec: pq_mod.PQCodec,
                  codes: jax.Array, sym_tables: jax.Array,
                  cache: cache_mod.CacheState, counters: IOCounters,
                  new_vec: jax.Array, entry_ids: jax.Array, *,
                  e_pos: int, k: int, s: int, rerank: str = "casr",
                  beam_width: int = 4, max_hops: int = 512,
                  tombstone: jax.Array | None = None,
                  page_seen: jax.Array | None = None,
                  visited: str = "hash",
                  new_id: jax.Array | None = None) -> InsertResult:
    """One in-place insertion.  ``rerank``: "casr" | "full" (static).

    The caller encodes the new vector into the target slot of ``codes``
    *before* calling (PQ codes live in host memory and are updated
    synchronously).  ``tombstone`` masks deleted vertices out of neighbor
    selection; ``page_seen`` seeds the traversal's page buffer (bulk
    merges); ``new_id`` commits into a reclaimed slot instead of
    appending at ``store.count`` (free-list reuse).
    """
    seek = position_seek(
        store, spec, codec, codes, cache, counters, new_vec, entry_ids,
        e_pos=e_pos, k=k, s=s, rerank=rerank, beam_width=beam_width,
        max_hops=max_hops, tombstone=tombstone, page_seen=page_seen,
        visited=visited)
    nid = (store.count if new_id is None else new_id).astype(jnp.int32)
    sres = commit_insert(store, spec, seek.cache, seek.counters, new_vec,
                         seek.nbrs, codes, sym_tables, new_id=nid)
    return InsertResult(store=sres.store, cache=sres.cache,
                        counters=sres.counters,
                        new_id=nid,
                        pool_ids=seek.pool_ids, hops=seek.hops,
                        rerank_rounds=seek.rerank_rounds,
                        page_seen=seek.page_seen)
