"""In-place insertion: ① position seeking → ② structural update.

Position seeking is a full graph traversal with a large explored pool
(|E_pos| ≫ |E_search|) whose only job is to surface ~R adequate neighbors
for the new vertex — the paper's diagnosis is that this step dominates
update cost.  The traversal itself reuses :func:`search.disk_traverse`;
the rerank is either the packed-layout full rerank or CASR.

The structural update wires the new vertex to its selected neighbors,
adds reciprocal edges (pruning the farthest edge by symmetric-PQ distance
when a neighbor is already at max degree R), and charges the layout's
write costs:

* packed:   (1 + #modified neighbors) full pages — every neighbor's vector
            is rewritten although the update never touched it (Fig. 4b).
* decoupled: the modified edgelists are gathered out-of-place onto fresh
            edge pages (⌈M/edgelists_per_page⌉ page writes) plus exactly
            one vector write for the new vertex.

RMW reads are free here: the wired neighbors come from the converged
explored pool, so their edge pages were read during this very insert's
traversal and still sit in the insert's RMW staging buffer (§8.2) — the
paper charges the same way.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cache as cache_mod
from repro.core import casr as casr_mod
from repro.core import pq as pq_mod
from repro.core import search as search_mod
from repro.core.iomodel import IOCounters, PAGE_BYTES
from repro.core.layout import GraphStore, LayoutSpec, relocate_edgelists

INF = jnp.float32(3.4e38)


# ---------------------------------------------------------------------------
# Neighbor selection (paper §5.2-5.3)
# ---------------------------------------------------------------------------

def select_neighbors(pool_ids: jax.Array, casr_res, r: int) -> jax.Array:
    """Order the pool for wiring: the CASR-loaded close portion ranked by
    exact distance first, then the unloaded remainder in PQ order (shortcut
    slots need diversity, not exactness).  Returns [r] ids (-1 padded)."""
    P = pool_ids.shape[0]
    valid = pool_ids >= 0
    arange = jnp.arange(P, dtype=jnp.float32)
    # loaded → exact distance;  unloaded-valid → big + PQ rank (stable);
    # invalid → +inf.  exact distances are always ≪ 1e30.
    key = jnp.where(casr_res.loaded & valid, casr_res.exact_d,
                    jnp.where(valid, 1e30 + arange, INF))
    order = jnp.argsort(key)
    return jnp.where(valid[order], pool_ids[order], -1)[:r]


def full_pool_neighbors(pool_ids: jax.Array, r: int) -> jax.Array:
    """Baseline neighbor selection: pool already exact-reranked — take R."""
    return pool_ids[:r]


# ---------------------------------------------------------------------------
# Structural update
# ---------------------------------------------------------------------------

class StructuralResult(NamedTuple):
    store: GraphStore
    cache: cache_mod.CacheState
    counters: IOCounters
    n_wired: jax.Array      # reciprocal edges actually added


def _wire_reciprocal(store: GraphStore, nbrs: jax.Array, new_id: jax.Array,
                     codes: jax.Array, sym_tables: jax.Array):
    """Add new_id into each neighbor's edgelist (prune farthest if full).

    Returns (edges, degree, modified[r] bool).
    """
    r_slots = nbrs.shape[0]

    def wire(carry, i):
        edges, degree = carry
        p = nbrs[i]

        def do(args):
            edges, degree = args
            row = edges[p]
            occupied = row >= 0
            free = jnp.argmin(occupied)                  # first empty slot
            has_free = ~occupied.all()
            p_code = codes[p]
            row_codes = codes[jnp.maximum(row, 0)]
            d_row = jnp.where(
                occupied,
                pq_mod.sym_distance(sym_tables, p_code, row_codes), -INF)
            worst = jnp.argmax(d_row)
            d_new = pq_mod.sym_distance(sym_tables, p_code,
                                        codes[new_id][None])[0]
            tgt = jnp.where(has_free, free, worst)
            write = has_free | (d_new < d_row[worst])
            new_row = jnp.where(write, row.at[tgt].set(new_id), row)
            new_deg = jnp.where(write & has_free, degree[p] + 1, degree[p])
            return (edges.at[p].set(new_row),
                    degree.at[p].set(new_deg)), write

        def skip(args):
            return args, jnp.bool_(False)

        dup = jnp.any((nbrs == p) & (jnp.arange(r_slots) < i))
        (edges, degree), modified = lax.cond(
            (p >= 0) & (p != new_id) & ~dup, do, skip, (edges, degree))
        return (edges, degree), modified

    (edges, degree), modified = lax.scan(
        wire, (store.edges, store.degree), jnp.arange(r_slots))
    return edges, degree, modified


def _charge_writes(counters: IOCounters, spec: LayoutSpec,
                   n_modified_nbrs: jax.Array,
                   edge_pages_written: jax.Array) -> IOCounters:
    """Write-side accounting for one insertion under either layout."""
    el = spec.edgelist_bytes
    vb = spec.vector_bytes
    if spec.kind == "packed":
        ppv = spec.packed_pages_per_vertex
        n_pages = (1 + n_modified_nbrs) * ppv
        edge_b = (1 + n_modified_nbrs) * el
        vec_b = jnp.int64(vb)                        # the new vertex (useful)
        wasted_b = (n_modified_nbrs * vb).astype(jnp.int64)  # co-written
        pad = (n_pages * PAGE_BYTES - edge_b - vec_b - wasted_b)
        return dataclasses.replace(
            counters,
            write_requests=counters.write_requests + n_pages.astype(jnp.int64),
            edge_bytes_written=counters.edge_bytes_written +
            edge_b.astype(jnp.int64),
            vec_bytes_written=counters.vec_bytes_written + vec_b,
            wasted_vec_bytes_written=counters.wasted_vec_bytes_written +
            wasted_b,
            pad_bytes_written=counters.pad_bytes_written +
            pad.astype(jnp.int64))
    # decoupled: out-of-place edge pages + exactly one vector write
    vec_pages = spec.vector_pages_per_read
    edge_b = ((1 + n_modified_nbrs) * el).astype(jnp.int64)
    edge_pad = edge_pages_written.astype(jnp.int64) * PAGE_BYTES - edge_b
    return dataclasses.replace(
        counters,
        write_requests=counters.write_requests +
        edge_pages_written.astype(jnp.int64) + vec_pages,
        edge_bytes_written=counters.edge_bytes_written + edge_b,
        vec_bytes_written=counters.vec_bytes_written + jnp.int64(vb),
        pad_bytes_written=counters.pad_bytes_written + edge_pad +
        jnp.int64(vec_pages * PAGE_BYTES - vb))


def structural_update(store: GraphStore, spec: LayoutSpec,
                      cache: cache_mod.CacheState, counters: IOCounters,
                      new_vec: jax.Array, nbrs: jax.Array,
                      codes: jax.Array, sym_tables: jax.Array
                      ) -> StructuralResult:
    """② Commit vertex ``store.count`` with neighbor list ``nbrs`` [R]."""
    new_id = store.count.astype(jnp.int32)
    r = store.r

    # the new vertex's own record
    vectors = store.vectors.at[new_id].set(new_vec.astype(
        store.vectors.dtype))
    nbrs = jnp.where(nbrs == new_id, -1, nbrs)               # no self loops
    edges = store.edges.at[new_id].set(nbrs)
    degree = store.degree.at[new_id].set((nbrs >= 0).sum())
    store = dataclasses.replace(store, vectors=vectors, edges=edges,
                                degree=degree)

    # reciprocal wiring + prune
    edges, degree, modified = _wire_reciprocal(store, nbrs, new_id, codes,
                                               sym_tables)
    store = dataclasses.replace(store, edges=edges, degree=degree,
                                count=store.count + 1)

    n_modified = modified.sum()
    if spec.kind == "packed":
        # in-place page rewrites; the new vertex gets a fresh page group
        edge_page = store.edge_page.at[new_id].set(store.next_page)
        page_live = store.page_live.at[store.next_page].add(1)
        store = dataclasses.replace(store, edge_page=edge_page,
                                    page_live=page_live,
                                    next_page=store.next_page + 1)
        counters = _charge_writes(counters, spec, n_modified,
                                  jnp.int32(0))
        return StructuralResult(store, cache, counters, n_modified)

    # decoupled: gather new + modified edgelists onto fresh pages
    moved_ids = jnp.concatenate([jnp.array([new_id], jnp.int32),
                                 jnp.where(modified, nbrs, -1)])
    moved_valid = moved_ids >= 0
    old_pages = jnp.where(moved_valid,
                          store.edge_page[jnp.maximum(moved_ids, 0)], -1)
    store, pages_written = relocate_edgelists(store, moved_ids, moved_valid,
                                              spec)
    counters = _charge_writes(counters, spec, n_modified, pages_written)

    # §8.2 eviction hints: any old edge page left with zero live slots
    def hint(cache, i):
        pg = old_pages[i]
        dead = (pg >= 0) & (store.page_live[jnp.maximum(pg, 0)] <= 0)
        return lax.cond(dead,
                        lambda c: cache_mod.invalidate_page(c, pg),
                        lambda c: c, cache), None

    cache, _ = lax.scan(hint, cache, jnp.arange(moved_ids.shape[0]))
    return StructuralResult(store, cache, counters, n_modified)


# ---------------------------------------------------------------------------
# Full insertion (position seek + rerank + wire)
# ---------------------------------------------------------------------------

class InsertResult(NamedTuple):
    store: GraphStore
    cache: cache_mod.CacheState
    counters: IOCounters
    new_id: jax.Array
    pool_ids: jax.Array       # E_pos (PQ-sorted) — reused by NAVIS-update
    hops: jax.Array
    rerank_rounds: jax.Array
    page_seen: jax.Array      # pages this insert's traversal touched


def insert_vertex(store: GraphStore, spec: LayoutSpec, codec: pq_mod.PQCodec,
                  codes: jax.Array, sym_tables: jax.Array,
                  cache: cache_mod.CacheState, counters: IOCounters,
                  new_vec: jax.Array, entry_ids: jax.Array, *,
                  e_pos: int, k: int, s: int, rerank: str = "casr",
                  beam_width: int = 4, max_hops: int = 512,
                  tombstone: jax.Array | None = None,
                  page_seen: jax.Array | None = None) -> InsertResult:
    """One in-place insertion.  ``rerank``: "casr" | "full" (static).

    The caller encodes the new vector into ``codes[store.count]`` *before*
    calling (PQ codes live in host memory and are updated synchronously).
    ``tombstone`` masks deleted vertices out of neighbor selection;
    ``page_seen`` seeds the traversal's page buffer (bulk merges).
    """
    lut = pq_mod.adc_lut(codec, new_vec)
    res = search_mod.disk_traverse(
        store, spec, lut, codes, cache, counters, entry_ids,
        pool_size=e_pos, beam_width=beam_width, max_hops=max_hops,
        page_seen=page_seen)
    counters = res.counters
    cache = res.cache
    if tombstone is not None:
        res = res._replace(pool_ids=jnp.where(
            tombstone[jnp.maximum(res.pool_ids, 0)], -1, res.pool_ids))

    if rerank == "casr":
        cres = casr_mod.casr_rerank(store, spec, new_vec, res.pool_ids,
                                    counters, k=k, s=s)
        counters = cres.counters
        nbrs = select_neighbors(res.pool_ids, cres, store.r)
        rounds = cres.rerank_rounds
    else:
        ids, _, _, counters = search_mod.full_rerank(
            store, spec, new_vec, res, counters, k=res.pool_ids.shape[0])
        nbrs = full_pool_neighbors(ids, store.r)
        rounds = jnp.int32(1)

    sres = structural_update(store, spec, cache, counters, new_vec, nbrs,
                             codes, sym_tables)
    return InsertResult(store=sres.store, cache=sres.cache,
                        counters=sres.counters,
                        new_id=sres.store.count - 1,
                        pool_ids=res.pool_ids, hops=res.hops,
                        rerank_rounds=rounds, page_seen=res.page_seen)
