"""The NAVIS engine: composition of layout × rerank × entrance × cache ×
update-path.  Every paper baseline is a configuration, not a fork:

=================  =========  ======  ========  ==============  ===========
system             layout     rerank  entrance  cache           update path
=================  =========  ======  ========  ==============  ===========
freshdiskann       packed     full    static    none            buffered
odinann            packed     full    static    none            inplace
odinann_cache      packed     full    static    navis (packed)  inplace
layout_only        decoupled  full    static    none            inplace
sel_vec            decoupled  casr    static    none            inplace
navis              decoupled  casr    dynamic   navis           inplace
=================  =========  ======  ========  ==============  ===========

All per-op functions are jitted pure functions over :class:`EngineState`;
batches run under ``lax.scan`` so the cache/entrance/counter state threads
exactly as a concurrent run would interleave it.  The batch-parallel
fan-outs (``search_many``, ``insert_many``) instead run their whole wave
against one frozen snapshot — searches end to end, inserts for the
position-seek phase — and fold the wave's page-access traces back into
the shared cache; ``insert_many`` then serialises only the conflict-aware
structural commits.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cache as cache_mod
from repro.core import casr as casr_mod
from repro.core import entrance as ent_mod
from repro.core import graph as graph_mod
from repro.core import insert as insert_mod
from repro.core import maintenance as maint_mod
from repro.core import pq as pq_mod
from repro.core import search as search_mod
from repro.core.iomodel import (IOCounters, PAGE_BYTES, merge_counters,
                                sum_counters)
from repro.core.layout import GraphStore, LayoutSpec
from repro.kernels import ops as kernel_ops

INF = jnp.float32(3.4e38)


# ---------------------------------------------------------------------------
# Specification
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Static engine configuration (hashable — one jit per spec)."""

    dim: int
    r: int = 96
    n_max: int = 0                      # capacity incl. future inserts
    pq_m: int = 32                      # PQ subquantizers
    layout: str = "decoupled"           # packed | decoupled
    rerank: str = "casr"                # full | casr
    entrance: str = "dynamic"           # none | static | dynamic
    cache_policy: str = "navis"         # none | navis | lru | clock | lfu
    update_path: str = "inplace"        # inplace | buffered
    e_search: int = 40
    e_pos: int = 100
    k: int = 10
    beam_width: int = 4
    max_hops: int = 256
    visited_impl: str = "hash"          # hash (O(1) state) | bitmap (ref)
    s_search: int = 4                   # CASR group size (search path)
    s_pos: int = 8                      # CASR group size (position seeking)
    cache_capacity_pages: int = 1024
    ent_frac: float = 0.01
    r_ent: int = 32
    n_entry: int = 10
    ent_pool: int = 32
    buffer_frac: float = 0.06           # FreshDiskANN merge threshold
    buffer_max: int = 4096
    consolidate_frac: float = 0.2       # tombstone fraction triggering maint.
    maint_block: int = 256              # rows repaired per maintenance step
    maint_refine: bool = True           # re-RobustPrune young rows per pass

    @property
    def lspec(self) -> LayoutSpec:
        return LayoutSpec(kind=self.layout, dim=self.dim, r=self.r)

    def with_(self, **kw) -> "EngineSpec":
        return dataclasses.replace(self, **kw)


PRESETS = {
    "freshdiskann": dict(layout="packed", rerank="full", entrance="static",
                         cache_policy="none", update_path="buffered"),
    "odinann": dict(layout="packed", rerank="full", entrance="static",
                    cache_policy="none", update_path="inplace"),
    "odinann_cache": dict(layout="packed", rerank="full", entrance="static",
                          cache_policy="navis", update_path="inplace"),
    "layout_only": dict(layout="decoupled", rerank="full", entrance="static",
                        cache_policy="none", update_path="inplace"),
    "sel_vec": dict(layout="decoupled", rerank="casr", entrance="static",
                    cache_policy="none", update_path="inplace"),
    "navis": dict(layout="decoupled", rerank="casr", entrance="dynamic",
                  cache_policy="navis", update_path="inplace"),
}


def preset(name: str, dim: int, **overrides) -> EngineSpec:
    kw = dict(PRESETS[name])
    kw.update(overrides)
    return EngineSpec(dim=dim, **kw)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    store: GraphStore
    codes: jax.Array                 # [N_max, M] uint8
    ent: ent_mod.EntranceGraph
    cache: cache_mod.CacheState
    tombstone: jax.Array             # [N_max] bool — deleted vertices
    default_entries: jax.Array       # [n_entry] fallback entry ids
    ctr_search: IOCounters
    ctr_insert: IOCounters
    buf_vecs: jax.Array              # [B_max, D] FreshDiskANN memory buffer
    buf_count: jax.Array
    n_deleted: jax.Array
    free_list: jax.Array             # [N_max] reclaimed slot ids (stack)
    free_count: jax.Array            # live entries in free_list
    free_mask: jax.Array             # [N_max] bool — slot reclaimed, unused
    maint_cursor: jax.Array          # repair-sweep position (maintenance)
    young_mask: jax.Array            # [N_max] inserted since last refine
    ctr_maint: IOCounters            # consolidation I/O (SSD-model priced)

    @property
    def live_count(self):
        return self.store.count - self.n_deleted

    @property
    def live_mask(self):
        """[N_max] bool — slots holding a live (searchable) vector.  With
        deletions and slot reuse the live set is NOT the count prefix:
        benchmarks/tests must judge ground truth against this mask."""
        return (jnp.arange(self.store.n_max) < self.store.count) & \
            ~self.tombstone


class OpStats(NamedTuple):
    """Per-operation I/O summary for latency/throughput modelling."""
    read_requests: jax.Array
    read_bytes: jax.Array
    write_requests: jax.Array
    write_bytes: jax.Array
    serial_rounds: jax.Array      # dependent I/O rounds (hops + rerank)
    cache_hits: jax.Array
    cache_misses: jax.Array
    dropped: jax.Array = jnp.zeros((), bool)   # insert rejected (capacity)


def _delta_stats(before: IOCounters, after: IOCounters,
                 rounds, dropped=None) -> OpStats:
    if dropped is None:
        dropped = jnp.zeros((), bool)
    return OpStats(
        read_requests=after.read_requests - before.read_requests,
        read_bytes=after.total_read_bytes() - before.total_read_bytes(),
        write_requests=after.write_requests - before.write_requests,
        write_bytes=after.total_write_bytes() - before.total_write_bytes(),
        serial_rounds=rounds,
        cache_hits=after.cache_hits - before.cache_hits,
        cache_misses=after.cache_misses - before.cache_misses,
        dropped=dropped)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class Engine:
    """Composable GVS engine.  Build once, then thread `EngineState`
    through jitted ``search`` / ``insert`` / ``delete`` ops."""

    def __init__(self, spec: EngineSpec):
        self.spec = spec
        self.codec: Optional[pq_mod.PQCodec] = None
        self._sym: Optional[jax.Array] = None
        self._jit_ops()

    def _jit_ops(self):
        self.search = jax.jit(self._search)
        self.insert = jax.jit(self._insert)
        self.search_batch = jax.jit(self._search_batch)
        self.search_many = jax.jit(self._search_many)
        self.insert_batch = jax.jit(self._insert_batch)
        self.insert_many = jax.jit(self._insert_many)
        self.merge = jax.jit(self._merge)
        self.delete_many = jax.jit(self._delete_many)
        self._repair_block = jax.jit(functools.partial(
            maint_mod.repair_block, spec=self.spec.lspec,
            block=self.spec.maint_block))
        self._finalize_cycle = jax.jit(functools.partial(
            maint_mod.reclaim_and_defrag, spec=self.spec.lspec))
        self._admit_entrance_pages = jax.jit(maint_mod.admit_entrance_pages)
        self._refine_block = jax.jit(functools.partial(
            maint_mod.refine_block, spec=self.spec.lspec,
            e_pos=self.spec.e_pos, beam_width=self.spec.beam_width,
            max_hops=self.spec.max_hops, visited=self.spec.visited_impl))

    # -- construction -------------------------------------------------------

    def build(self, key: jax.Array, base_vectors: jax.Array,
              *, build_block: int = 64, build_e_pos: int = 64,
              alpha: float = 1.2, shared=None) -> EngineState:
        """Build (or adopt) the base index.

        ``shared``: an optional ``(codec, codes, store)`` bundle from a
        previous build — the proximity graph is layout-independent, so
        benchmark sweeps build it once and re-page it per engine config
        (packed vs decoupled page maps differ; edges/vectors do not).
        """
        spec = self.spec
        n_base, dim = base_vectors.shape
        assert dim == spec.dim
        n_max = spec.n_max or n_base
        k_pq, k_ent, k_build = jax.random.split(key, 3)

        if shared is not None:
            self.codec, codes, store0 = shared
            self._sym = pq_mod.sym_tables(self.codec)
            from repro.core.layout import assign_initial_pages
            store = assign_initial_pages(store0, spec.lspec)
        else:
            if self.codec is None:
                # PQ codec from a base sample; codes for the full capacity.
                # A pre-installed codec is kept (sharded deployments train
                # ONE codec on the global corpus — per-shard codecs would
                # make PQ distances incomparable across shards).
                sample = base_vectors[
                    jax.random.choice(k_pq, n_base, (min(n_base, 4096),),
                                      replace=False)]
                self.codec = pq_mod.train_pq(k_pq, sample, spec.pq_m)
            self._sym = pq_mod.sym_tables(self.codec)
            codes = jnp.zeros((n_max, spec.pq_m), jnp.uint8)
            codes = codes.at[:n_base].set(pq_mod.encode(self.codec,
                                                        base_vectors))

            store = graph_mod.build_graph(
                k_build, jnp.pad(base_vectors,
                                 ((0, n_max - n_base), (0, 0))),
                n_base, spec.lspec, self.codec, codes, n_max=n_max,
                e_pos=build_e_pos, block=build_block, alpha=alpha)

        c_max = max(int(spec.ent_frac * n_max * 2), 64)
        if spec.entrance == "none":
            ent = ent_mod.empty_entrance(c_max, spec.r_ent, n_max)
        else:
            ent = ent_mod.build_entrance(
                k_ent, codes, self._sym, n_base, c_max=c_max,
                r_ent=spec.r_ent, sample_frac=spec.ent_frac, n_max=n_max)

        cache = cache_mod.init_cache(
            store.page_live.shape[0], spec.cache_capacity_pages,
            spec.cache_policy, jax.random.fold_in(key, 7))
        med = graph_mod.medoid(base_vectors, n_base)
        default_entries = jnp.concatenate([
            med[None], jax.random.choice(
                jax.random.fold_in(key, 9), n_base,
                (spec.n_entry - 1,)).astype(jnp.int32)])

        return EngineState(
            store=store, codes=codes, ent=ent, cache=cache,
            tombstone=jnp.zeros((n_max,), bool),
            default_entries=default_entries,
            ctr_search=IOCounters.zeros(), ctr_insert=IOCounters.zeros(),
            buf_vecs=jnp.zeros((spec.buffer_max, dim), jnp.float32),
            buf_count=jnp.zeros((), jnp.int32),
            n_deleted=jnp.zeros((), jnp.int32),
            free_list=jnp.full((n_max,), -1, jnp.int32),
            free_count=jnp.zeros((), jnp.int32),
            free_mask=jnp.zeros((n_max,), bool),
            maint_cursor=jnp.zeros((), jnp.int32),
            young_mask=jnp.zeros((n_max,), bool),
            ctr_maint=IOCounters.zeros())

    def bundle(self, state: EngineState):
        """(codec, codes, store) — reusable across engine configs."""
        return (self.codec, state.codes, state.store)

    # -- entry-point selection ----------------------------------------------

    def _entries(self, state: EngineState, lut: jax.Array):
        """① entry selection.  Returns (entry_ids [n_entry], e_ent [pool])."""
        spec = self.spec
        if spec.entrance == "none":
            return state.default_entries, jnp.full(
                (spec.ent_pool,), -1, jnp.int32)

        def use_ent(_):
            entries, e_ent, _ = search_mod.entrance_search(
                state.ent, lut, state.codes, n_entry=spec.n_entry,
                pool_size=spec.ent_pool, visited=spec.visited_impl)
            return entries, e_ent

        def use_default(_):
            return state.default_entries, jnp.full(
                (spec.ent_pool,), -1, jnp.int32)

        return lax.cond(state.ent.count > 0, use_ent, use_default, None)

    # -- classification (Fig 4a) --------------------------------------------

    def _reclassify(self, counters: IOCounters, q, pool_ids, store,
                    loaded_count) -> IOCounters:
        """Move the CASR-classifier 'useful' share of provisionally-wasted
        vector reads into the useful bucket (packed piggybacking & the
        decoupled-full strawman both over-charge wasted)."""
        spec = self.spec
        n_useful = casr_mod.casr_stop_point(
            q, store.vectors, pool_ids, k=spec.k, s=1)
        n_useful = jnp.minimum(n_useful, loaded_count).astype(jnp.int64)
        moved = n_useful * spec.lspec.vector_bytes
        moved = jnp.minimum(moved, counters.wasted_vec_bytes_read)
        return dataclasses.replace(
            counters,
            useful_vec_bytes_read=counters.useful_vec_bytes_read + moved,
            wasted_vec_bytes_read=counters.wasted_vec_bytes_read - moved)

    # -- search --------------------------------------------------------------

    def _search_core(self, state: EngineState, q: jax.Array, *,
                     frozen: bool):
        """Shared ②③ body of one search: traverse + rerank + buffer merge.

        ``frozen=False``: the cache threads through (sequential path).
        ``frozen=True``: the cache is a read-only snapshot and the charged
        page accesses come back as ``res.trace`` — the vmap-safe fan-out
        path.  Returns (ids, dists, stats, counters, traverse result).
        """
        spec = self.spec
        ctr0 = IOCounters.zeros()
        lut = pq_mod.adc_lut(self.codec, q)
        entries, _ = self._entries(state, lut)

        res = search_mod.disk_traverse(
            state.store, spec.lspec, lut, state.codes, state.cache, ctr0,
            entries, pool_size=spec.e_search, beam_width=spec.beam_width,
            max_hops=spec.max_hops, frozen_cache=frozen,
            visited=spec.visited_impl)
        ctr = res.counters
        dead = (res.pool_ids >= 0) & \
            state.tombstone[jnp.maximum(res.pool_ids, 0)]
        ctr = dataclasses.replace(
            ctr, tombstone_skips=ctr.tombstone_skips +
            dead.sum().astype(jnp.int64))
        pool = jnp.where(dead, -1, res.pool_ids)

        if spec.rerank == "casr":
            cres = casr_mod.casr_rerank(state.store, spec.lspec, q, pool,
                                        ctr, k=spec.k, s=spec.s_search)
            ids, dists, ctr = cres.topk_ids, cres.topk_d, cres.counters
            rounds = res.hops + cres.rerank_rounds
        else:
            sorted_ids, sorted_d, _, ctr = search_mod.full_rerank(
                state.store, spec.lspec, q, res._replace(pool_ids=pool),
                ctr, k=spec.k)
            ids, dists = sorted_ids, sorted_d
            extra = 0 if spec.layout == "packed" else 1
            rounds = res.hops + 1 + extra
            ctr = self._reclassify(ctr, q, pool, state.store,
                                   (pool >= 0).sum())

        # FreshDiskANN: merge in-memory buffer hits (exact, no I/O)
        if spec.update_path == "buffered":
            ids, dists = self._merge_buffer_hits(state, q, ids, dists)

        stats = _delta_stats(ctr0, ctr, rounds)
        return ids, dists, stats, ctr, res

    def _search(self, state: EngineState, q: jax.Array):
        """Top-k search.  Returns (ids [k], dists [k], stats, state)."""
        ids, dists, stats, ctr, res = self._search_core(state, q,
                                                        frozen=False)
        state = dataclasses.replace(
            state, cache=res.cache,
            ctr_search=merge_counters(state.ctr_search, ctr))
        return ids, dists, stats, state

    def _merge_buffer_hits(self, state, q, ids, dists):
        spec = self.spec
        bvalid = jnp.arange(spec.buffer_max) < state.buf_count
        bd = jnp.where(bvalid, kernel_ops.rerank_l2(q, state.buf_vecs), INF)
        # buffer ids are virtual: n_max + slot (not yet in the graph)
        bids = (state.store.n_max + jnp.arange(spec.buffer_max)).astype(
            jnp.int32)
        d, i = kernel_ops.pool_merge(jnp.where(ids >= 0, dists, INF), ids,
                                     bd, bids)
        return jnp.where(d < INF, i, -1), d

    # -- insert ---------------------------------------------------------------

    def _insert(self, state: EngineState, v: jax.Array):
        """One insertion.  Returns (stats, state)."""
        if self.spec.update_path == "buffered":
            return self._insert_buffered(state, v)
        return self._insert_inplace(state, v)

    def _insert_inplace(self, state: EngineState, v: jax.Array,
                        page_seen=None, charge_bulk: bool = False):
        spec = self.spec

        # capacity guard: with no free (reclaimed) slot left past n_max the
        # whole insertion is masked and the stats carry ``dropped`` — an
        # unguarded insert would silently lose the scatter writes
        # (codes.at[count], vectors.at[new_id]) while count kept
        # incrementing, corrupting main_to_ent and live_count.
        full = (state.store.count >= state.store.n_max) & \
            (state.free_count <= 0)

        def do(state: EngineState):
            ctr0 = IOCounters.zeros()
            lut = pq_mod.adc_lut(self.codec, v)
            entries, e_ent = self._entries(state, lut)

            # maintenance-reclaimed slots are reused before fresh ones:
            # under sustained churn the free list is what keeps the
            # acceptance rate at 100% once count reaches n_max
            reuse = state.free_count > 0
            slot = jnp.where(
                reuse,
                state.free_list[jnp.maximum(state.free_count - 1, 0)],
                state.store.count).astype(jnp.int32)
            new_code = pq_mod.encode(self.codec, v[None])[0]
            codes = state.codes.at[slot].set(new_code)

            ires = insert_mod.insert_vertex(
                state.store, spec.lspec, self.codec, codes, self._sym,
                state.cache, ctr0, v, entries, e_pos=spec.e_pos, k=spec.k,
                s=spec.s_pos, rerank=spec.rerank,
                beam_width=spec.beam_width, max_hops=spec.max_hops,
                tombstone=state.tombstone, page_seen=page_seen,
                visited=spec.visited_impl, new_id=slot)
            ctr = ires.counters
            if spec.rerank == "full":
                ctr = self._reclassify(ctr, v, ires.pool_ids, ires.store,
                                       (ires.pool_ids >= 0).sum())

            ent = state.ent
            cache = ires.cache
            if spec.entrance == "dynamic":
                ent = ent_mod.navis_update(
                    ent, ires.new_id, new_code, ires.pool_ids, e_ent,
                    ires.store.count, codes, self._sym,
                    r_ent_frac=spec.ent_frac)
                if spec.cache_policy == "navis":
                    # entrance-aware cache hint (§7): a freshly promoted
                    # member's edgelist page seeds future traversals
                    promoted = ent.count > state.ent.count
                    page = ires.store.edge_page[slot]
                    cache = lax.cond(
                        promoted,
                        lambda c: cache_mod.priority_admit(c, page),
                        lambda c: c, cache)

            stats = _delta_stats(ctr0, ctr, ires.hops + ires.rerank_rounds)
            state = dataclasses.replace(
                state, store=ires.store, codes=codes, ent=ent,
                cache=cache,
                tombstone=state.tombstone.at[slot].set(False),
                n_deleted=state.n_deleted - reuse.astype(jnp.int32),
                free_count=state.free_count - reuse.astype(jnp.int32),
                free_mask=state.free_mask.at[slot].set(False),
                young_mask=state.young_mask.at[slot].set(True),
                ctr_insert=merge_counters(state.ctr_insert, ctr))
            return stats, state, ires.page_seen

        def skip(state: EngineState):
            stats = _delta_stats(IOCounters.zeros(), IOCounters.zeros(),
                                 jnp.zeros((), jnp.int32),
                                 dropped=jnp.ones((), bool))
            # must match the do-branch's page buffer structure: the seeded
            # buffer when given, else an empty set of the same kind/shape
            # disk_traverse would have created
            seen = (page_seen if page_seen is not None else
                    search_mod.empty_page_seen(
                        state.store, visited=spec.visited_impl,
                        max_hops=spec.max_hops,
                        beam_width=spec.beam_width))
            return stats, state, seen

        return lax.cond(full, skip, do, state)

    def _insert_buffered(self, state: EngineState, v: jax.Array):
        """FreshDiskANN path: append to the host buffer (zero storage I/O);
        the caller triggers :meth:`merge` at the 6% threshold."""
        # past capacity the insert is dropped outright: the slot write is
        # clamped AND masked (an unclamped slot would silently scatter-drop
        # while buf_count kept growing, corrupting the _merge_buffer_hits
        # validity mask and needs_merge), and the counter saturates.
        full = state.buf_count >= self.spec.buffer_max
        slot = jnp.minimum(state.buf_count, self.spec.buffer_max - 1)
        state = dataclasses.replace(
            state,
            buf_vecs=state.buf_vecs.at[slot].set(
                jnp.where(full, state.buf_vecs[slot], v)),
            buf_count=state.buf_count + jnp.where(full, 0, 1))
        zeros = jnp.zeros((), jnp.int64)
        stats = OpStats(zeros, zeros, zeros, zeros,
                        jnp.zeros((), jnp.int32), zeros, zeros,
                        dropped=full)
        return stats, state, jnp.zeros_like(state.store.page_live,
                                            dtype=bool)

    def needs_merge(self, state: EngineState) -> jax.Array:
        thresh = jnp.maximum(
            (self.spec.buffer_frac *
             state.store.count.astype(jnp.float32)).astype(jnp.int32), 1)
        return (state.buf_count >= jnp.minimum(thresh,
                                               self.spec.buffer_max)) & \
            (state.buf_count > 0)

    def _merge(self, state: EngineState):
        """FreshDiskANN StreamingMerge: position-seek every buffered vector
        (reads amortised through one shared page buffer), wire them, then
        stream-rewrite the whole on-disk index into the double buffer
        (full-index read + write — the paper's documented write overhead).
        Returns (merge_stats, state)."""
        spec = self.spec
        ctr_before = state.ctr_insert
        page_seen0 = jnp.zeros_like(state.store.page_live, dtype=bool)

        def step(carry, i):
            state, page_seen = carry

            def do(args):
                state, page_seen = args
                _, state, seen = self._insert_inplace(
                    state, state.buf_vecs[i], page_seen=page_seen)
                return state, page_seen | seen

            state, page_seen = lax.cond(
                i < state.buf_count, do, lambda a: a, (state, page_seen))
            return (state, page_seen), None

        (state, _), _ = lax.scan(step, (state, page_seen0),
                                 jnp.arange(spec.buffer_max))

        # stream-rewrite: every live page read once + written once
        lspec = spec.lspec
        per = (lspec.packed_per_page if spec.layout == "packed"
               else lspec.edgelists_per_page)
        n_pages = (-(-state.store.count // per)).astype(jnp.int64)
        stream_bytes = n_pages * PAGE_BYTES
        ctr = dataclasses.replace(
            state.ctr_insert,
            read_requests=state.ctr_insert.read_requests + n_pages,
            write_requests=state.ctr_insert.write_requests + n_pages,
            pad_bytes_read=state.ctr_insert.pad_bytes_read + stream_bytes,
            pad_bytes_written=state.ctr_insert.pad_bytes_written +
            stream_bytes)
        state = dataclasses.replace(state, ctr_insert=ctr,
                                    buf_count=jnp.zeros((), jnp.int32))
        stats = _delta_stats(ctr_before, state.ctr_insert,
                             jnp.int32(0))
        return stats, state

    # -- delete (paper §11) ---------------------------------------------------

    def delete(self, state: EngineState, vid: jax.Array) -> EngineState:
        """Tombstone ``vid``: removed from results and future wiring; the
        entrance graph drops its member.  Bulk compaction happens at the
        merge threshold (not modelled — deletion is benign per OdinANN).

        Idempotent: deleting an already-tombstoned id is a no-op (a second
        n_deleted increment would drift live_count negative-ward and
        misfire the buffered-merge threshold).  Dropping an entrance
        member also scrubs every reciprocal edge pointing at its slot —
        otherwise ``entrance_search`` could seed traversals from the dead
        vertex through the dangling references.
        """
        already = state.tombstone[vid]
        ent = state.ent
        eslot = ent.main_to_ent[vid]

        def drop_ent(ent):
            slot = jnp.maximum(eslot, 0)
            # the dead slot keeps its own outgoing edges (they point at
            # live members and let a traversal route *through* the hole),
            # but no live row may point back at it
            edges = jnp.where(ent.edges == eslot, -1, ent.edges)
            return dataclasses.replace(
                ent,
                ids=ent.ids.at[slot].set(-1),
                edges=edges,
                main_to_ent=ent.main_to_ent.at[vid].set(-1))

        ent = lax.cond((eslot >= 0) & ~already, drop_ent, lambda e: e, ent)
        return dataclasses.replace(
            state, ent=ent,
            tombstone=state.tombstone.at[vid].set(True),
            n_deleted=state.n_deleted + jnp.where(already, 0, 1))

    def _delete_many(self, state: EngineState,
                     vids: jax.Array) -> EngineState:
        """Tombstone a batch of ids ([B] int32; -1 entries are skipped)."""
        def step(state, vid):
            return lax.cond(vid >= 0,
                            lambda s: self.delete(s, vid),
                            lambda s: s, state), None

        state, _ = lax.scan(step, state, vids)
        return state

    # -- maintenance (ISSUE 4: reclamation + repair + defrag + refresh) -------

    def needs_consolidation(self, state: EngineState,
                            lookahead: int = 0) -> jax.Array:
        """True when a consolidation pass is due: the *unreclaimed*
        tombstone fraction crossed ``spec.consolidate_frac``, or capacity
        pressure — fewer than ``lookahead`` insertable slots remain
        (fresh headroom + free list) while tombstones are waiting to be
        reclaimed.  ``lookahead`` is the upcoming insert demand (e.g. the
        next wave size); 0 means "consolidate only when already full"."""
        pending = state.n_deleted - state.free_count
        count = jnp.maximum(state.store.count, 1)
        frac = pending.astype(jnp.float32) / count.astype(jnp.float32)
        headroom = (state.store.n_max - state.store.count) + \
            state.free_count
        return (pending > 0) & (
            (frac >= self.spec.consolidate_frac) |
            (headroom < jnp.maximum(lookahead, 1)))

    def maintenance_step(self, state: EngineState):
        """One bounded increment of the consolidation cycle.

        While the repair cursor is inside the vertex range, repairs the
        next ``spec.maint_block`` rows (splicing dead-vertex references
        away — :func:`repro.core.maintenance.repair_block`) and advances.
        Once the sweep is complete, finalizes the cycle: reclaim every
        tombstoned slot into the free list, clear the reclaimed rows,
        defrag the edgelist pages (invalidating moved pages in the
        cache), rebuild the entrance graph + default entries over the
        live set, priority-admit the new members' pages, and reset the
        cursor.  All I/O lands in ``state.ctr_maint``.

        Host-orchestrated (the entrance rebuild sizes its sample from the
        concrete live count); each stage is jitted.  Returns
        (state, done) — ``done`` marks cycle completion.
        """
        spec = self.spec
        cur = int(state.maint_cursor)
        if cur < int(state.store.count):
            store, cache, ctr, _ = self._repair_block(
                state.store, state.codes, self._sym, state.tombstone,
                state.cache, state.ctr_maint, jnp.asarray(cur, jnp.int32))
            state = dataclasses.replace(
                state, store=store, cache=cache, ctr_maint=ctr,
                maint_cursor=jnp.asarray(cur + spec.maint_block,
                                         jnp.int32))
            return state, False

        # -- cycle finalization ------------------------------------------
        import numpy as np

        # ①b: re-RobustPrune the vertices churn inserted since the last
        # pass — the runtime insert path wires by nearest-PQ without the
        # build's α-diversity, so without this stage a corpus whose
        # membership turns over drifts to unrefined-graph recall
        if spec.maint_refine:
            young = np.asarray(state.young_mask) & \
                (np.arange(state.store.n_max) < int(state.store.count)) & \
                ~np.asarray(state.tombstone)
            yids = np.flatnonzero(young)
            if len(yids):
                store, ctr = state.store, state.ctr_maint
                rb = 32
                for s in range(0, len(yids), rb):
                    blk = np.full((rb,), -1, np.int32)
                    blk[:len(yids[s:s + rb])] = yids[s:s + rb]
                    store, ctr, _ = self._refine_block(
                        store, state.codes, self.codec.codebooks,
                        self._sym, state.tombstone, state.cache, ctr,
                        jnp.asarray(blk), jnp.asarray(blk >= 0),
                        state.default_entries)
                state = dataclasses.replace(
                    state, store=store, ctr_maint=ctr,
                    young_mask=jnp.zeros_like(state.young_mask))

        (store, free_list, free_count, free_mask, cache, ctr,
         _) = self._finalize_cycle(
            state.store, state.tombstone, state.free_list,
            state.free_count, state.free_mask, state.cache,
            state.ctr_maint)
        state = dataclasses.replace(
            state, store=store, free_list=free_list, free_count=free_count,
            free_mask=free_mask, cache=cache, ctr_maint=ctr,
            maint_cursor=jnp.zeros((), jnp.int32))

        live_ids = jnp.asarray(np.flatnonzero(np.asarray(state.live_mask)),
                               jnp.int32)
        key = jax.random.fold_in(
            jax.random.PRNGKey(1347),
            int(store.count) * 131071 + int(state.n_deleted))
        ent = state.ent
        if spec.entrance != "none" and live_ids.shape[0] >= 2:
            # dynamic entrances top themselves back up through Algorithm 2
            # as inserts flow (navis_update's live-membership trigger);
            # static ones only ever refresh here
            ent = maint_mod.refresh_entrance(
                key, state.codes, self._sym, state.ent, state.tombstone,
                live_ids, sample_frac=spec.ent_frac, r_ent=spec.r_ent,
                n_max=store.n_max,
                top_up=spec.entrance != "dynamic")
            cache = self._admit_entrance_pages(cache, store, ent)
        default_entries = state.default_entries
        if live_ids.shape[0] > 0:
            default_entries = maint_mod.refresh_default_entries(
                jax.random.fold_in(key, 1), store.vectors, live_ids,
                spec.n_entry)
        state = dataclasses.replace(state, ent=ent, cache=cache,
                                    default_entries=default_entries)
        return state, True

    def consolidate(self, state: EngineState):
        """One full consolidation pass: repair sweep over the whole vertex
        range, then reclaim + defrag + entrance refresh.  Returns
        (OpStats, state) — the stats price the pass on the SSD model
        exactly like any foreground op (serial_rounds = sweep steps)."""
        ctr0 = state.ctr_maint
        state = dataclasses.replace(state,
                                    maint_cursor=jnp.zeros((), jnp.int32))
        steps, done = 0, False
        while not done:
            state, done = self.maintenance_step(state)
            steps += 1
        stats = _delta_stats(ctr0, state.ctr_maint,
                             jnp.asarray(steps, jnp.int32))
        return stats, state

    # -- batches --------------------------------------------------------------

    def _search_batch(self, state: EngineState, queries: jax.Array):
        """Sequential (state-threading) batch search under lax.scan."""
        def step(state, q):
            ids, dists, stats, state = self._search(state, q)
            return state, (ids, dists, stats)

        state, (ids, dists, stats) = lax.scan(step, state, queries)
        return ids, dists, stats, state

    def _search_many(self, state: EngineState, queries: jax.Array):
        """Batch-parallel search fan-out: the whole batch runs concurrently
        (vmap) against one shared snapshot of the engine state.

        Searches only *read* the graph, so a snapshot is safe: ids and
        distances are identical to :meth:`search_batch` (the cache never
        alters results, only I/O charging).  Each query probes the frozen
        cache and records its page-access trace; afterwards the traces are
        replayed in query order into one merged cache and the per-query
        counters are summed — the paper's model of concurrent readers
        sharing a single host cache.  Returns (ids [Q,k], dists [Q,k],
        per-query stats, state).
        """
        def one(q):
            ids, dists, stats, ctr, res = self._search_core(state, q,
                                                            frozen=True)
            return ids, dists, stats, ctr, res.trace

        ids, dists, stats, ctrs, traces = jax.vmap(one)(queries)
        _, cache = cache_mod.apply_traces(state.cache, traces)
        state = dataclasses.replace(
            state, cache=cache,
            ctr_search=merge_counters(state.ctr_search,
                                      sum_counters(ctrs)))
        return ids, dists, stats, state

    def _insert_batch(self, state: EngineState, vectors: jax.Array):
        def step(state, v):
            stats, state, _ = self._insert(state, v)
            return state, stats

        state, stats = lax.scan(step, state, vectors)
        return stats, state

    def _insert_many(self, state: EngineState, vectors: jax.Array,
                     valid: jax.Array | None = None):
        """Batch-parallel insert fan-out: the whole insert wave position-
        seeks concurrently, only the tiny structural commits serialise.

        Phase ① vmaps :func:`insert.position_seek` (traversal + CASR/full
        rerank + neighbor selection) against one frozen snapshot of the
        engine state — the read-heavy part that dominates update cost runs
        for all ``B`` inserts at once, each charging its own I/O counters
        and recording its page-access trace against the cache snapshot.
        The traces are then replayed in wave order into one merged cache,
        mirroring ``search_many``.

        Phase ② commits the structural updates serially under ``lax.scan``
        with conflict-aware re-validation: each commit re-checks its
        snapshot-selected neighbors against edgelists already mutated by
        earlier commits in the same wave (re-pruning by symmetric-PQ
        distance, dropping tombstoned/duplicate picks) and charges an RMW
        re-read for every neighbor edge page a prior commit dirtied — the
        snapshot copy in its staging buffer is stale — so counters stay
        honest versus the sequential path.  Commits past capacity are
        masked and flagged ``dropped``.

        ``valid`` masks padding lanes (sharded insert buckets): an invalid
        lane charges no I/O, replays no trace and commits nothing.
        Returns (per-insert OpStats [B], state).
        """
        spec = self.spec
        B = vectors.shape[0]
        ok = jnp.ones((B,), bool) if valid is None else valid

        if spec.update_path == "buffered":
            # nothing to fan out: buffered inserts do no position seeking
            def step(state, xs):
                v, keep = xs

                def do(state):
                    stats, state, _ = self._insert_buffered(state, v)
                    return stats, state

                def skip(state):
                    z = jnp.zeros((), jnp.int64)
                    return OpStats(z, z, z, z, jnp.zeros((), jnp.int32),
                                   z, z, jnp.zeros((), bool)), state

                stats, state = lax.cond(keep, do, skip, state)
                return state, stats

            state, stats = lax.scan(step, state, (vectors, ok))
            return stats, state

        # -- phase ①: concurrent position seek on the frozen snapshot -----
        new_codes = pq_mod.encode(self.codec, vectors)          # [B, M]

        def seek_one(v):
            ctr0 = IOCounters.zeros()
            lut = pq_mod.adc_lut(self.codec, v)
            entries, e_ent = self._entries(state, lut)
            seek = insert_mod.position_seek(
                state.store, spec.lspec, self.codec, state.codes,
                state.cache, ctr0, v, entries, e_pos=spec.e_pos,
                k=spec.k, s=spec.s_pos, rerank=spec.rerank,
                beam_width=spec.beam_width, max_hops=spec.max_hops,
                tombstone=state.tombstone, frozen_cache=True,
                visited=spec.visited_impl)
            ctr = seek.counters
            if spec.rerank == "full":
                ctr = self._reclassify(ctr, v, seek.pool_ids, state.store,
                                       (seek.pool_ids >= 0).sum())
            return (seek.nbrs, seek.pool_ids, ctr, seek.hops,
                    seek.rerank_rounds, seek.trace, e_ent)

        nbrs_all, pools, ctrs, hops, rounds, traces, e_ents = \
            jax.vmap(seek_one)(vectors)

        # padding lanes charge nothing and replay nothing
        ctrs = jax.tree.map(lambda x: jnp.where(ok, x, jnp.zeros_like(x)),
                            ctrs)
        hops = jnp.where(ok, hops, 0)
        rounds = jnp.where(ok, rounds, 0)
        traces = jnp.where(ok[:, None], traces, -1)

        # the wave's reads merge into the shared cache in wave order
        _, cache = cache_mod.apply_traces(state.cache, traces)

        # -- phase ②: serialized conflict-aware commits -------------------
        # commits draw reclaimed slots from the free list before fresh
        # ones, so the free structures (and the tombstone bits the reused
        # slots clear) thread through the scan carry
        n_max = state.store.n_max
        dirty0 = jnp.zeros_like(state.store.page_live, dtype=bool)

        def commit(carry, xs):
            (store, codes, ent, cache, dirty, tombstone,
             free_list, free_count, free_mask, n_deleted,
             young_mask) = carry
            v, nbrs, code, pool, e_ent, keep = xs
            can = keep & ((store.count < n_max) | (free_count > 0))

            def do(args):
                (store, codes, ent, cache, dirty, tombstone,
                 free_list, free_count, free_mask, n_deleted,
                 young_mask) = args
                reuse = free_count > 0
                new_id = jnp.where(
                    reuse, free_list[jnp.maximum(free_count - 1, 0)],
                    store.count).astype(jnp.int32)
                codes = codes.at[new_id].set(code)
                nbrs2 = insert_mod.revalidate_neighbors(
                    nbrs, new_id, code, codes, self._sym, tombstone)
                ctr, _ = insert_mod.charge_rmw_rereads(
                    IOCounters.zeros(), spec.lspec, store, nbrs2, dirty)
                sres = insert_mod.commit_insert(
                    store, spec.lspec, cache, ctr, v, nbrs2, codes,
                    self._sym, new_id=new_id)
                cache = sres.cache
                dirty = insert_mod.mark_dirty_pages(
                    dirty, sres.store, new_id, nbrs2, sres.modified)
                if spec.entrance == "dynamic":
                    ent2 = ent_mod.navis_update(
                        ent, new_id, code, pool, e_ent, sres.store.count,
                        codes, self._sym, r_ent_frac=spec.ent_frac)
                    if spec.cache_policy == "navis":
                        promoted = ent2.count > ent.count
                        page = sres.store.edge_page[new_id]
                        cache = lax.cond(
                            promoted,
                            lambda c: cache_mod.priority_admit(c, page),
                            lambda c: c, cache)
                    ent = ent2
                tombstone = tombstone.at[new_id].set(False)
                n_deleted = n_deleted - reuse.astype(jnp.int32)
                free_count = free_count - reuse.astype(jnp.int32)
                free_mask = free_mask.at[new_id].set(False)
                young_mask = young_mask.at[new_id].set(True)
                return ((sres.store, codes, ent, cache, dirty, tombstone,
                         free_list, free_count, free_mask, n_deleted,
                         young_mask),
                        sres.counters)

            def skip(args):
                return args, IOCounters.zeros()

            carry, ctr = lax.cond(
                can, do, skip,
                (store, codes, ent, cache, dirty, tombstone,
                 free_list, free_count, free_mask, n_deleted, young_mask))
            return carry, (ctr, keep & ~can)

        ((store, codes, ent, cache, _, tombstone, free_list, free_count,
          free_mask, n_deleted, young_mask),
         (commit_ctrs, dropped)) = lax.scan(
            commit,
            (state.store, state.codes, state.ent, cache, dirty0,
             state.tombstone, state.free_list, state.free_count,
             state.free_mask, state.n_deleted, state.young_mask),
            (vectors, nbrs_all, new_codes, pools, e_ents, ok))

        per = merge_counters(ctrs, commit_ctrs)            # [B]-leading
        stats = OpStats(
            read_requests=per.read_requests,
            read_bytes=per.total_read_bytes(),
            write_requests=per.write_requests,
            write_bytes=per.total_write_bytes(),
            serial_rounds=hops + rounds,
            cache_hits=per.cache_hits,
            cache_misses=per.cache_misses,
            dropped=dropped)
        state = dataclasses.replace(
            state, store=store, codes=codes, ent=ent, cache=cache,
            tombstone=tombstone, free_list=free_list,
            free_count=free_count, free_mask=free_mask,
            n_deleted=n_deleted, young_mask=young_mask,
            ctr_insert=merge_counters(state.ctr_insert,
                                      sum_counters(per)))
        return stats, state

    # -- calibration (paper §5.2 warm-up) -------------------------------------

    def calibrate(self, state: EngineState, queries: jax.Array) -> EngineSpec:
        """Set s_search / s_pos from the P25 of the vectors-to-converge
        distribution over ~100 warm-up queries.  Returns the updated spec
        (also installed on self, re-jitting the ops)."""
        spec = self.spec

        @functools.partial(jax.jit, static_argnames=("pool_size",))
        def pools(state, queries, pool_size):
            def one(q):
                lut = pq_mod.adc_lut(self.codec, q)
                entries, _ = self._entries(state, lut)
                res = search_mod.disk_traverse(
                    state.store, spec.lspec, lut, state.codes, state.cache,
                    IOCounters.zeros(), entries, pool_size=pool_size,
                    beam_width=spec.beam_width, max_hops=spec.max_hops,
                    visited=spec.visited_impl)
                return res.pool_ids
            return jax.lax.map(one, queries, batch_size=16)

        s_vals = {}
        for name, pool_size in (("s_search", spec.e_search),
                                ("s_pos", spec.e_pos)):
            ps = pools(state, queries, pool_size)
            s = casr_mod.calibrate_group_size(
                jax.random.PRNGKey(0), state.store.vectors, ps, queries,
                k=spec.k)
            s_vals[name] = max(s, 1)
        new_spec = spec.with_(**s_vals)
        self.spec = new_spec
        self._jit_ops()
        return new_spec
