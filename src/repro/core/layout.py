"""On-"disk" storage layouts: packed (baseline) vs locality-driven decoupling.

Packed layout (DiskANN/OdinANN/Starling lineage): one 4 KiB page per vertex
holding ``[vector][degree][edgelist]`` — every edge fetch drags the vector in,
and a structural update rewrites the whole page.

Locality-driven decoupling (NAVIS §5.1): an *edgelist file* packing multiple
edgelists per page, a *vector file*, and a host-memory *indirection table*
mapping vertex → (edge page, slot).  Edge updates are out-of-place: modified
edgelists are gathered onto a fresh page and the indirection pointers are
flipped; fully-invalidated pages are recycled.  Because co-updated vertices
are graph-adjacent, the fresh page preserves page-level locality.

Everything is a fixed-capacity JAX pytree so search/insert jit cleanly; the
"file" is the arrays, the "I/O" is the counters (iomodel.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.iomodel import PAGE_BYTES


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphStore:
    """The proximity graph + vectors + layout bookkeeping.

    edges[v]      : int32 [N_max, R], -1-padded neighbor ids
    degree[v]     : int32 [N_max]
    vectors[v]    : float32 [N_max, D] full-precision vectors
    count         : number of live vertices
    edge_page[v]  : indirection: which edge page holds v's edgelist
    page_live[p]  : live edgelists on page p (0 ⇒ recyclable)
    next_page     : bump allocator for fresh edge pages
    """

    edges: jax.Array
    degree: jax.Array
    vectors: jax.Array
    count: jax.Array
    edge_page: jax.Array
    page_live: jax.Array
    next_page: jax.Array

    @property
    def n_max(self) -> int:
        return self.edges.shape[0]

    @property
    def r(self) -> int:
        return self.edges.shape[1]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]


@dataclasses.dataclass(frozen=True)
class LayoutSpec:
    """Static layout geometry (bytes per record, records per page)."""

    kind: str                  # "packed" | "decoupled"
    dim: int
    r: int
    vec_dtype_bytes: int = 4

    @property
    def vector_bytes(self) -> int:
        return self.dim * self.vec_dtype_bytes

    @property
    def edgelist_bytes(self) -> int:
        return 8 + 4 * self.r          # id + degree + edge ids

    @property
    def packed_record_bytes(self) -> int:
        return self.vector_bytes + self.edgelist_bytes

    @property
    def packed_pages_per_vertex(self) -> int:
        return -(-self.packed_record_bytes // PAGE_BYTES)

    @property
    def packed_per_page(self) -> int:
        """Records per page in the packed layout (low-dim co-residency)."""
        return max(PAGE_BYTES // self.packed_record_bytes, 1)

    @property
    def edgelists_per_page(self) -> int:
        """Decoupled: edgelists co-resident on one 4 KiB edge page."""
        return max(PAGE_BYTES // self.edgelist_bytes, 1)

    @property
    def vector_pages_per_read(self) -> int:
        return -(-self.vector_bytes // PAGE_BYTES)

    def read_pad_bytes(self, kind_pages: int, payload: int) -> int:
        return kind_pages * PAGE_BYTES - payload


def empty_store(n_max: int, dim: int, r: int) -> GraphStore:
    # page capacity: worst case one fresh page per insert + initial pages
    p_max = 2 * n_max
    return GraphStore(
        edges=jnp.full((n_max, r), -1, jnp.int32),
        degree=jnp.zeros((n_max,), jnp.int32),
        vectors=jnp.zeros((n_max, dim), jnp.float32),
        count=jnp.zeros((), jnp.int32),
        edge_page=jnp.full((n_max,), -1, jnp.int32),
        page_live=jnp.zeros((p_max,), jnp.int32),
        next_page=jnp.zeros((), jnp.int32),
    )


def assign_initial_pages(store: GraphStore, spec: LayoutSpec) -> GraphStore:
    """Greedy page placement for the base index (Starling-style: consecutive
    ids — which the builder lays out in graph-adjacency order — share pages).

    packed: vertex v lives on its own page group (high-dim) or co-residency
    group (low-dim).  decoupled: ``edgelists_per_page`` neighbors per page.
    """
    n = store.n_max
    if spec.kind == "packed":
        per = spec.packed_per_page
    else:
        per = spec.edgelists_per_page
    pages = jnp.arange(n, dtype=jnp.int32) // per
    n_pages = -(-n // per)
    live = jnp.zeros_like(store.page_live).at[:n_pages].set(
        jnp.minimum(per, n - jnp.arange(n_pages) * per).astype(jnp.int32))
    return dataclasses.replace(
        store, edge_page=pages, page_live=live,
        next_page=jnp.asarray(n_pages, jnp.int32))


# ---------------------------------------------------------------------------
# Out-of-place edge update (decoupled layout, NAVIS §5.1)
# ---------------------------------------------------------------------------

def relocate_edgelists(store: GraphStore, vertex_ids: jax.Array,
                       valid: jax.Array, spec: LayoutSpec):
    """Move the modified vertices' edgelists onto a fresh page.

    vertex_ids: int32 [M] (with ``valid`` mask) — the co-updated vertices of
    one insertion (new vertex + its wired neighbors).  They are gathered onto
    ⌈M/edgelists_per_page⌉ fresh pages; old slots are invalidated and fully
    dead pages recycled implicitly via ``page_live``.

    Returns (store, pages_written:int32).
    """
    per = spec.edgelists_per_page
    m = vertex_ids.shape[0]
    n_new_pages = -(-m // per)

    safe_ids = jnp.where(valid, vertex_ids, 0)
    old_pages = store.edge_page[safe_ids]
    # decrement live counts of old pages (once per valid vertex)
    dec = jnp.zeros_like(store.page_live).at[old_pages].add(
        jnp.where(valid & (old_pages >= 0), 1, 0))
    page_live = store.page_live - dec

    base = store.next_page
    slot_page = base + (jnp.arange(m, dtype=jnp.int32) // per)
    edge_page = store.edge_page.at[safe_ids].set(
        jnp.where(valid, slot_page, store.edge_page[safe_ids]))
    inc = jnp.zeros_like(page_live).at[slot_page].add(
        jnp.where(valid, 1, 0))
    page_live = page_live + inc

    n_valid = valid.sum()
    pages_written = jnp.where(n_valid > 0, -(-n_valid // per), 0)
    store = dataclasses.replace(
        store, edge_page=edge_page, page_live=page_live,
        next_page=base + jnp.asarray(n_new_pages, jnp.int32))
    return store, pages_written.astype(jnp.int64)


# ---------------------------------------------------------------------------
# Defragmentation (maintenance pass, ISSUE 4)
# ---------------------------------------------------------------------------

def defrag_edgelists(store: GraphStore, holders: jax.Array,
                     spec: LayoutSpec):
    """Re-pack every page-holding vertex's edgelist contiguously from page 0.

    Sustained out-of-place updates scatter co-traversed edgelists across
    fresh pages (page-level locality drifts) and monotonically burn the
    bump allocator; a defrag pass restores the build-time id-contiguous
    placement — consecutive ids (graph-adjacency order, as the builder
    lays them out) share pages again — and *resets* ``next_page``, so the
    page-id space is bounded by churn-per-maintenance-cycle instead of
    lifetime insert count.

    ``holders``: [n_max] bool — vertices that must keep an edge page
    (live vertices, plus any tombstoned vertex not yet reclaimed).
    Everything else gets ``edge_page = -1``.

    Returns (store, changed_pages [p_max] bool, n_pages int32) —
    ``changed_pages`` marks every page id whose *contents* differ after
    the move (old homes of moved vertices + their new homes); the caller
    must invalidate those in the host cache.
    """
    per = (spec.packed_per_page if spec.kind == "packed"
           else spec.edgelists_per_page)
    p_max = store.page_live.shape[0]
    rank = jnp.cumsum(holders.astype(jnp.int32)) - 1
    new_page = jnp.where(holders, rank // per, -1).astype(jnp.int32)
    n_hold = holders.sum().astype(jnp.int32)
    n_pages = jnp.where(n_hold > 0, -(-n_hold // per), 0).astype(jnp.int32)

    page_live = jnp.zeros_like(store.page_live).at[
        jnp.where(holders, new_page, p_max)].add(1)        # OOB = dropped
    moved = store.edge_page != new_page
    changed = jnp.zeros((p_max,), bool)
    changed = changed.at[jnp.where(moved & (store.edge_page >= 0),
                                   store.edge_page, p_max)].set(True)
    changed = changed.at[jnp.where(moved & holders, new_page,
                                   p_max)].set(True)
    store = dataclasses.replace(store, edge_page=new_page,
                                page_live=page_live, next_page=n_pages)
    return store, changed, n_pages
