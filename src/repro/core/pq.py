"""Product quantisation: codebook training (Lloyd), encoding, ADC distances.

PQ vectors live in host memory in the paper (and in VMEM-tiled form on TPU —
see kernels/pq_adc.py for the Pallas version; this module is the pure-jnp
reference used by the engine and as the kernel oracle).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PQCodec:
    codebooks: jax.Array      # [M, 256, dsub] float32

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]

    @property
    def dim(self) -> int:
        return self.m * self.dsub


def train_pq(key: jax.Array, sample: jax.Array, m: int,
             iters: int = 8) -> PQCodec:
    """Lloyd k-means per subspace.  sample: [S, D]; D % m == 0."""
    s, d = sample.shape
    assert d % m == 0, (d, m)
    dsub = d // m
    sub = sample.reshape(s, m, dsub).transpose(1, 0, 2)      # [M, S, dsub]
    init_idx = jax.random.choice(key, s, (256,), replace=s < 256)
    cents = sub[:, init_idx]                                  # [M, 256, dsub]

    def step(cents, _):
        d2 = (jnp.sum(sub ** 2, -1)[:, :, None]
              - 2 * jnp.einsum("msd,mkd->msk", sub, cents)
              + jnp.sum(cents ** 2, -1)[:, None, :])          # [M, S, 256]
        assign = jnp.argmin(d2, -1)                           # [M, S]
        onehot = jax.nn.one_hot(assign, 256, dtype=sub.dtype)  # [M, S, 256]
        sums = jnp.einsum("msk,msd->mkd", onehot, sub)
        counts = onehot.sum(1)[..., None]
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return PQCodec(codebooks=cents)


def encode(codec: PQCodec, x: jax.Array) -> jax.Array:
    """x: [N, D] -> codes uint8 [N, M]."""
    n, d = x.shape
    sub = x.reshape(n, codec.m, codec.dsub).transpose(1, 0, 2)
    d2 = (jnp.sum(sub ** 2, -1)[:, :, None]
          - 2 * jnp.einsum("mnd,mkd->mnk", sub, codec.codebooks)
          + jnp.sum(codec.codebooks ** 2, -1)[:, None, :])
    return jnp.argmin(d2, -1).T.astype(jnp.uint8)             # [N, M]


def adc_lut(codec: PQCodec, q: jax.Array) -> jax.Array:
    """Asymmetric-distance LUT for query q: [M, 256] of squared-L2 parts."""
    qs = q.reshape(codec.m, 1, codec.dsub)
    return jnp.sum((codec.codebooks - qs) ** 2, -1)           # [M, 256]


def adc_distance(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """codes: [B, M] uint8 -> squared-L2 estimates [B]."""
    m = lut.shape[0]
    idx = codes.astype(jnp.int32)                             # [B, M]
    vals = jnp.take_along_axis(lut, idx.T, axis=1)            # [M, B]
    return vals.sum(0)


def exact_l2(q: jax.Array, x: jax.Array) -> jax.Array:
    """Squared L2 between q [D] and rows of x [B, D]."""
    diff = x - q[None]
    return jnp.sum(diff * diff, axis=-1)


def decode_codes(codec: PQCodec, codes: jax.Array) -> jax.Array:
    """Reconstruct ('deflate') PQ codes back into approximate vectors.

    codes: [N, M] uint8 -> [N, M*dsub] float32.
    """
    idx = codes.astype(jnp.int32)                             # [N, M]
    gathered = jax.vmap(lambda cb, ix: cb[ix], in_axes=(0, 1),
                        out_axes=1)(codec.codebooks, idx)      # [N, M, dsub]
    return gathered.reshape(codes.shape[0], -1)


# ---------------------------------------------------------------------------
# Symmetric (code-to-code) distances — used where no full vector is in memory
# (entrance-graph maintenance, structural-update pruning).
# ---------------------------------------------------------------------------

def sym_tables(codec: PQCodec) -> jax.Array:
    """Cross-centroid distance tables T[m, a, b] = ||c_ma - c_mb||^2."""
    cb = codec.codebooks                                      # [M, 256, dsub]
    d2 = (jnp.sum(cb ** 2, -1)[:, :, None]
          - 2 * jnp.einsum("mad,mbd->mab", cb, cb)
          + jnp.sum(cb ** 2, -1)[:, None, :])
    return jnp.maximum(d2, 0.0)                               # [M, 256, 256]


def sym_distance(tables: jax.Array, code_a: jax.Array,
                 code_b: jax.Array) -> jax.Array:
    """code_a: [M]; code_b: [B, M] -> approx squared L2 [B]."""
    m = tables.shape[0]
    a = code_a.astype(jnp.int32)                              # [M]
    b = code_b.astype(jnp.int32)                              # [B, M]
    rows = tables[jnp.arange(m), a]                           # [M, 256]
    return jnp.take_along_axis(rows, b.T, axis=1).sum(0)      # [B]


def sym_distance_matrix(tables: jax.Array, codes: jax.Array) -> jax.Array:
    """All-pairs symmetric PQ distances for a code set [S, M] -> [S, S]."""
    return jax.vmap(lambda c: sym_distance(tables, c, codes))(codes)
