"""Convergence-Aware Speculative Reranking (CASR, Algorithm 1).

Replaces the full-pool exact rerank at the end of position seeking (and,
with a smaller pool, of search).  Vectors are fetched from the slow tier in
groups of ``s`` in PQ-distance order; each group's I/O submission overlaps
the previous group's exact-distance compute; the loop stops when the running
exact top-K stabilises.

The speculative pipeline means that when convergence is detected after
processing group *t*, group *t+1*'s I/O has already been issued — that
overrun is charged to the counters, exactly as the paper's io_uring
implementation pays it.  On TPU the same structure is a double-buffered
HBM→VMEM DMA (kernels/rerank_l2.py); this module is the engine-level
reference with full I/O accounting.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.iomodel import IOCounters, PAGE_BYTES
from repro.core.layout import GraphStore, LayoutSpec
from repro.kernels import ops as kernel_ops

INF = jnp.float32(3.4e38)


class CASRResult(NamedTuple):
    ids: jax.Array          # [pool] candidate ids (the input order)
    exact_d: jax.Array      # [pool] exact distances (INF where not loaded)
    loaded: jax.Array       # [pool] bool — vector fetched
    topk_ids: jax.Array     # [k] converged exact top-K (-1 padded)
    topk_d: jax.Array       # [k]
    n_loaded: jax.Array     # int32 — vectors fetched (incl. speculative)
    n_groups: jax.Array     # int32 — pipeline rounds executed
    rerank_rounds: jax.Array  # int32 — serial I/O rounds on the latency path
    counters: IOCounters


def _topk_ids(ids: jax.Array, d: jax.Array, k: int) -> tuple[jax.Array,
                                                             jax.Array]:
    """Smallest-k by d; ties broken by position (stable).  Runs through the
    kernel-dispatched pool merge (the candidate array is the "pool" prefix
    merged with its own tail)."""
    out_d, out_i = kernel_ops.pool_merge(d[:k], ids[:k], d[k:], ids[k:])
    return jnp.where(out_d < INF, out_i, -1), out_d


def _charge_vec_reads(counters: IOCounters, spec: LayoutSpec,
                      n: jax.Array, useful: bool = True) -> IOCounters:
    """n full-vector reads from the decoupled vector file."""
    pages = spec.vector_pages_per_read
    bytes_ = (n * pages * PAGE_BYTES).astype(jnp.int64)
    vec_payload = (n * spec.vector_bytes).astype(jnp.int64)
    pad = bytes_ - vec_payload
    field = "useful_vec_bytes_read" if useful else "wasted_vec_bytes_read"
    return dataclasses.replace(
        counters,
        read_requests=counters.read_requests + n.astype(jnp.int64),
        pad_bytes_read=counters.pad_bytes_read + pad,
        **{field: getattr(counters, field) + vec_payload})


def casr_rerank(store: GraphStore, spec: LayoutSpec, q: jax.Array,
                pool_ids: jax.Array, counters: IOCounters, *, k: int,
                s: int) -> CASRResult:
    """Algorithm 1 over a PQ-sorted candidate pool.

    ``pool_ids``: [P] main-graph ids sorted ascending by PQ distance
    (-1 padded at the tail).  Returns exact distances for the loaded prefix
    and the converged top-``k``.
    """
    P = pool_ids.shape[0]
    s = max(min(s, P), 1)
    max_groups = -(-P // s)
    valid = pool_ids >= 0
    safe = jnp.maximum(pool_ids, 0)

    def load_group(exact_d, loaded, counters, g):
        """Fetch vectors for group g (positions [g*s, g*s+s))."""
        start = g * s
        in_group = (jnp.arange(P) >= start) & (jnp.arange(P) < start + s)
        take = in_group & valid & ~loaded
        n = take.sum()
        counters = _charge_vec_reads(counters, spec, n)
        d = jnp.where(take, kernel_ops.rerank_l2(q, store.vectors[safe]),
                      exact_d)
        return d, loaded | take, counters, n

    # pipeline start: group 0 is loaded before the loop (Alg 1 line 3)
    exact_d = jnp.full((P,), INF)
    loaded = jnp.zeros((P,), bool)
    exact_d, loaded, counters, n0 = load_group(exact_d, loaded, counters,
                                               jnp.int32(0))

    # carry: (exact_d, loaded, topk_prev, next_group, done, rounds, counters)
    # Each iteration mirrors Alg 1's while body: speculatively issue group
    # ``next_group``'s I/O, then compute exact distances of the *previous*
    # group (already folded into exact_d by load_group — the compute is the
    # L2 inside load_group; the separation only matters for I/O accounting,
    # which is what we model), then run the convergence test.
    topk0 = jnp.full((k,), -1, jnp.int32)

    def cond(c):
        _, _, _, g, done, _, _, _ = c
        return ~done & (g <= max_groups)

    def body(c):
        exact_d, loaded, topk_prev, g, done, rounds, n_loaded, counters = c
        # speculative next-group I/O (charged even if we converge this round)
        def spec_load(args):
            exact_d, loaded, counters, n_loaded = args
            d, l, ctr, n = load_group(exact_d, loaded, counters, g)
            return d, l, ctr, n_loaded + n
        exact_d, loaded, counters, n_loaded = lax.cond(
            g < max_groups, spec_load,
            lambda a: a, (exact_d, loaded, counters, n_loaded))
        # convergence test over distances known so far (groups < g)
        known_d = jnp.where(loaded & (jnp.arange(P) < g * s), exact_d, INF)
        topk_new, _ = _topk_ids(pool_ids, known_d, k)
        stable = (topk_new == topk_prev).all() & (topk_prev >= 0).any()
        exhausted = g >= max_groups
        return (exact_d, loaded, topk_new, g + 1, stable | exhausted,
                rounds + 1, n_loaded, counters)

    carry = (exact_d, loaded, topk0, jnp.int32(1), jnp.bool_(False),
             jnp.int32(1), n0, counters)
    exact_d, loaded, topk_prev, g, _, rounds, n_loaded, counters = \
        lax.while_loop(cond, body, carry)

    known_d = jnp.where(loaded, exact_d, INF)
    topk_ids, topk_d = _topk_ids(pool_ids, known_d, k)
    # latency model: the speculative pipeline keeps the I/O stream
    # continuous (group t+1 is in flight while group t computes), so the
    # rerank adds ~2 dependent round-trips (fill + drain) regardless of
    # how many groups ran — that is the entire point of Algorithm 1.
    return CASRResult(ids=pool_ids, exact_d=exact_d, loaded=loaded,
                      topk_ids=topk_ids, topk_d=topk_d, n_loaded=n_loaded,
                      n_groups=g - 1,
                      rerank_rounds=jnp.minimum(rounds, 2),
                      counters=counters)


def casr_rerank_many(store: GraphStore, spec: LayoutSpec, qs: jax.Array,
                     pools: jax.Array, counters: IOCounters, *, k: int,
                     s: int) -> CASRResult:
    """Batched Algorithm 1: one CASR rerank per query, vectorised.

    The convergence ``while_loop`` carries per-query state only, so the
    whole batch runs under ``vmap`` (lanes that converge early idle until
    the slowest lane finishes — the SIMD analogue of the paper's
    per-thread early exit).  ``counters`` is the per-query starting tally
    (usually zeros); every CASRResult field gains a leading [Q] axis, so
    total I/O is ``iomodel.sum_counters(result.counters)``.  This is the
    rerank stage the engine's ``search_many`` fan-out executes.
    """
    return jax.vmap(
        lambda q, p: casr_rerank(store, spec, q, p, counters, k=k, s=s)
    )(qs, pools)


# ---------------------------------------------------------------------------
# Classifier + calibration
# ---------------------------------------------------------------------------

def casr_stop_point(q: jax.Array, vectors: jax.Array, pool_ids: jax.Array,
                    *, k: int, s: int = 1) -> jax.Array:
    """Number of vectors CASR (group size s) would load for this pool.

    Runs the convergence recurrence on *free* exact distances — used as the
    paper's "PQ-distance-based classifier" to split useful vs wasted vector
    I/O inside the packed-layout baselines (Fig. 4a), and by the warm-up
    calibration below.  Returns an int32 count (includes the speculative
    overrun group).
    """
    P = pool_ids.shape[0]
    valid = pool_ids >= 0
    d_all = jnp.where(valid, kernel_ops.rerank_l2(
        q, vectors[jnp.maximum(pool_ids, 0)]), INF)
    max_groups = -(-P // s)

    def topk_at(g):
        known = jnp.where(jnp.arange(P) < g * s, d_all, INF)
        return _topk_ids(pool_ids, known, k)[0]

    def cond(c):
        g, done = c
        return ~done & (g < max_groups)

    def body(c):
        g, _ = c
        stable = (topk_at(g) == topk_at(g + 1)).all() & \
            (topk_at(g) >= 0).any()
        return g + 1, stable

    g, _ = lax.while_loop(cond, body, (jnp.int32(1), jnp.bool_(False)))
    # loads = converged group count + one speculative group
    return jnp.minimum((g + 1) * s, valid.sum())


def calibrate_group_size(key: jax.Array, vectors: jax.Array,
                         pools: jax.Array, queries: jax.Array, *, k: int,
                         percentile: float = 25.0) -> int:
    """Warm-up calibration of s (paper §5.2): run the s=1 recurrence over
    ~100 queries' pools and take the P25 of the vectors-to-converge
    distribution."""
    stops = jax.vmap(
        lambda q, p: casr_stop_point(q, vectors, p, k=k, s=1))(queries,
                                                               pools)
    s = jnp.percentile(stops.astype(jnp.float32), percentile)
    return int(max(int(s), 1))
