"""Entrance graph: build + NAVIS-update (Algorithm 2).

The entrance graph is a small in-memory sample (~1%) of the proximity graph
with reduced out-degree ``R_ent`` that seeds every traversal with well-placed
entry points.  Prior systems freeze it after build; NAVIS keeps it fresh by
piggybacking each on-disk insertion's already-computed explored sets:

    E_inter = E_pos ∩ G_ent         (on-disk pool ∩ entrance members)
    q.nbr   = E_inter ⊕ E_ent       (fill to R_ent, E_inter first)
    reciprocal links + prune         (drop farthest by symmetric-PQ distance)

The paper's lock section becomes a functional state swap (DESIGN.md §2): the
whole update is a pure function ``EntranceGraph -> EntranceGraph`` executed
inside the insert jit, so readers always see a consistent snapshot.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import pq as pq_mod

INF = jnp.float32(3.4e38)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EntranceGraph:
    """Fixed-capacity in-memory entrance graph.

    ids[c]         : main-graph vertex id of entrance vertex c (-1 empty)
    edges[c]       : int32 [C_max, R_ent] indices into ``ids`` (-1 pad)
    count          : live entries
    main_to_ent[v] : inverse map main id -> entrance index (-1 absent);
                     sized to the main graph's N_max
    """

    ids: jax.Array
    edges: jax.Array
    count: jax.Array
    main_to_ent: jax.Array

    @property
    def c_max(self) -> int:
        return self.ids.shape[0]

    @property
    def r_ent(self) -> int:
        return self.edges.shape[1]


def empty_entrance(c_max: int, r_ent: int, n_max: int) -> EntranceGraph:
    return EntranceGraph(
        ids=jnp.full((c_max,), -1, jnp.int32),
        edges=jnp.full((c_max, r_ent), -1, jnp.int32),
        count=jnp.zeros((), jnp.int32),
        main_to_ent=jnp.full((n_max,), -1, jnp.int32))


# ---------------------------------------------------------------------------
# Build (sample + in-memory kNN on PQ codes)
# ---------------------------------------------------------------------------

def build_entrance(key: jax.Array, codes: jax.Array, sym_tables: jax.Array,
                   n_live: int, *, c_max: int, r_ent: int,
                   sample_frac: float = 0.01,
                   n_max: int | None = None,
                   live_ids: jax.Array | None = None) -> EntranceGraph:
    """Sample ``sample_frac`` of the live vertices and kNN-link them.

    Distances use symmetric PQ (code-to-code) so the build never touches the
    slow tier — matching the paper's "in-memory entrance graph" premise.
    The medoid-most vertex (min mean distance) is swapped to index 0, which
    ``entrance_search`` uses as its seed.

    ``live_ids``: optional [n_live] int32 main-graph ids to sample from —
    after deletions the live set is no longer the prefix ``[0, n_live)``,
    so a maintenance-pass entrance refresh passes the compacted live ids
    explicitly (fresh builds omit it and sample the prefix).
    """
    n_max = n_max or codes.shape[0]
    n_sample = max(min(int(n_live * sample_frac), c_max), min(n_live, 2))
    perm = jax.random.permutation(key, n_live)[:n_sample]
    perm = perm.astype(jnp.int32)
    if live_ids is not None:
        perm = live_ids[perm].astype(jnp.int32)
    return link_members(perm, codes, sym_tables, c_max=c_max, r_ent=r_ent,
                        n_max=n_max)


def link_members(members: jax.Array, codes: jax.Array,
                 sym_tables: jax.Array, *, c_max: int, r_ent: int,
                 n_max: int) -> EntranceGraph:
    """kNN-link an explicit member list [S] into an entrance graph (the
    build's linking stage, split out so a maintenance refresh can keep a
    chosen member set — e.g. the survivors of the previous entrance —
    instead of resampling from scratch).  The medoid-most member is
    swapped to slot 0, which ``entrance_search`` seeds from."""
    n_sample = members.shape[0]
    sample_codes = codes[members]                               # [S, M]
    d = pq_mod.sym_distance_matrix(sym_tables, sample_codes)    # [S, S]
    d = d + jnp.eye(n_sample) * INF
    # medoid to slot 0
    med = jnp.argmin(d.sum(axis=1))
    swap = jnp.arange(n_sample).at[0].set(med).at[med].set(0)
    members = members[swap]
    d = d[swap][:, swap]

    k = min(r_ent, n_sample - 1)
    _, nbr = lax.top_k(-d, k)                                   # [S, k]
    edges = jnp.full((c_max, r_ent), -1, jnp.int32)
    edges = edges.at[:n_sample, :k].set(nbr.astype(jnp.int32))

    ids = jnp.full((c_max,), -1, jnp.int32).at[:n_sample].set(members)
    main_to_ent = jnp.full((n_max,), -1, jnp.int32).at[members].set(
        jnp.arange(n_sample, dtype=jnp.int32))
    return EntranceGraph(ids=ids, edges=edges,
                         count=jnp.asarray(n_sample, jnp.int32),
                         main_to_ent=main_to_ent)


def add_member(ent: EntranceGraph, vid: jax.Array, codes: jax.Array,
               sym_tables: jax.Array) -> EntranceGraph:
    """Append one live vertex as an entrance member, wiring it to its
    ``R_ent`` symmetric-PQ-nearest existing members with reciprocal
    links + prune — the maintenance refresh's top-up primitive (a full
    member resample has brutal coverage variance at the ~1% sample size;
    adding into the existing structure preserves it).  No-op when ``vid``
    is already a member or the slot high-water mark hit ``c_max``."""
    r_ent = ent.r_ent
    want = (ent.count < ent.c_max) & (vid >= 0)
    want &= ent.main_to_ent[jnp.maximum(vid, 0)] < 0

    def do(ent: EntranceGraph) -> EntranceGraph:
        live = ent.ids >= 0
        d = jnp.where(live, pq_mod.sym_distance(
            sym_tables, codes[vid], codes[jnp.maximum(ent.ids, 0)]), INF)
        order = jnp.argsort(d)
        slots = jnp.arange(ent.c_max, dtype=jnp.int32)
        nbrs = jnp.where(live[order], slots[order], -1)[:r_ent]

        slot = ent.count
        ids = ent.ids.at[slot].set(vid)
        main_to_ent = ent.main_to_ent.at[vid].set(slot)
        edges = ent.edges.at[slot].set(nbrs)
        new_code = codes[vid]

        def wire(edges, i):
            p = nbrs[i]

            def wire_one(edges):
                row = edges[p]
                occupied = row >= 0
                free = jnp.argmin(occupied)
                has_free = ~occupied.all()
                p_code = codes[ids[p]]
                row_codes = codes[ids[jnp.maximum(row, 0)]]
                d_row = jnp.where(
                    occupied,
                    pq_mod.sym_distance(sym_tables, p_code, row_codes),
                    -INF)
                worst = jnp.argmax(d_row)
                d_q = pq_mod.sym_distance(sym_tables, p_code,
                                          new_code[None])[0]
                tgt = jnp.where(has_free, free, worst)
                write = has_free | (d_q < d_row[worst])
                new_row = jnp.where(
                    write, row.at[tgt].set(slot.astype(jnp.int32)), row)
                return edges.at[p].set(new_row)

            return lax.cond((p >= 0) & (p != slot), wire_one,
                            lambda e: e, edges), None

        edges, _ = lax.scan(wire, edges, jnp.arange(r_ent))
        return dataclasses.replace(
            ent, ids=ids, edges=edges, count=ent.count + 1,
            main_to_ent=main_to_ent)

    return lax.cond(want, do, lambda e: e, ent)


# ---------------------------------------------------------------------------
# NAVIS-update (Algorithm 2)
# ---------------------------------------------------------------------------

def navis_update(ent: EntranceGraph, new_id: jax.Array, new_code: jax.Array,
                 e_pos: jax.Array, e_ent: jax.Array, graph_count: jax.Array,
                 codes: jax.Array, sym_tables: jax.Array, *,
                 r_ent_frac: float = 0.01) -> EntranceGraph:
    """Algorithm 2.  All inputs are main-graph ids; -1 = padding.

    e_pos : [P] on-disk explored set from position seeking (PQ-sorted).
    e_ent : [E] entrance-graph explored set from entry-point selection.
    Triggered only while |G_ent| < r_ent_frac * |G| and capacity remains.

    ``new_code`` is the new vertex's PQ code: reciprocal pruning measures
    every candidate edge against it directly, so the update never gathers
    ``codes[new_id]`` (insert waves commit with the code in hand).
    """
    r_ent = ent.r_ent
    # coverage is judged on *live* membership, not the slot high-water
    # mark (``ent.count``): deletes scrub members without reclaiming
    # their slots, and comparing against count would permanently stall
    # promotions after churn — live membership is what lets Algorithm 2
    # top the entrance back up as inserts flow (self-healing entrance).
    n_members = (ent.ids >= 0).sum()
    want = (n_members.astype(jnp.float32)
            < r_ent_frac * graph_count.astype(jnp.float32))
    want &= ent.count < ent.c_max
    # a vertex already promoted must not be promoted twice
    want &= ent.main_to_ent[jnp.maximum(new_id, 0)] < 0
    want &= new_id >= 0

    def do_update(ent: EntranceGraph) -> EntranceGraph:
        # --- line 2: E_inter = E_pos ∩ G_ent (as entrance indices) ---------
        pos_ent = jnp.where(e_pos >= 0,
                            ent.main_to_ent[jnp.maximum(e_pos, 0)], -1)
        # --- line 3: neighbor candidates: E_inter first, then E_ent --------
        ent_ent = jnp.where(e_ent >= 0,
                            ent.main_to_ent[jnp.maximum(e_ent, 0)], -1)
        cand = jnp.concatenate([pos_ent, ent_ent])              # [P+E]
        # dedupe (keep first occurrence) with a scatter-min of positions
        c_max = ent.c_max
        arange = jnp.arange(cand.shape[0], dtype=jnp.int32)
        first = jnp.full((c_max,), jnp.iinfo(jnp.int32).max, jnp.int32)
        first = first.at[jnp.maximum(cand, 0)].min(
            jnp.where(cand >= 0, arange, jnp.iinfo(jnp.int32).max))
        keep = (cand >= 0) & (first[jnp.maximum(cand, 0)] == arange)
        # stable-compact the kept candidates to the front, take R_ent
        order = jnp.argsort(jnp.where(keep, arange, jnp.iinfo(jnp.int32).max))
        nbrs = jnp.where(keep[order], cand[order], -1)[:r_ent]  # [R_ent]

        # --- line 6: G_ent ∪ q ---------------------------------------------
        slot = ent.count
        ids = ent.ids.at[slot].set(new_id)
        main_to_ent = ent.main_to_ent.at[new_id].set(slot)
        edges = ent.edges.at[slot].set(nbrs)

        # --- lines 4-5, 7-8: reciprocal links with prune --------------------
        # for each neighbor p: append q; if full, drop the farthest edge by
        # symmetric-PQ distance to p (codes are in host memory — no I/O).
        def wire(edges, i):
            p = nbrs[i]

            def do(edges):
                row = edges[p]                                  # [R_ent]
                occupied = row >= 0
                free = jnp.argmin(occupied)                     # first -1
                has_free = ~occupied.all()
                # distance from p to each current edge and to q
                p_code = codes[ids[p]]
                row_codes = codes[ids[jnp.maximum(row, 0)]]
                d_row = jnp.where(
                    occupied,
                    pq_mod.sym_distance(sym_tables, p_code, row_codes), -INF)
                worst = jnp.argmax(d_row)
                d_q = pq_mod.sym_distance(sym_tables, p_code,
                                          new_code[None])[0]
                # if free slot: take it; else replace worst iff q is closer
                tgt = jnp.where(has_free, free, worst)
                write = has_free | (d_q < d_row[worst])
                new_row = jnp.where(
                    write, row.at[tgt].set(slot.astype(jnp.int32)), row)
                return edges.at[p].set(new_row)

            return lax.cond((p >= 0) & (p != slot), do, lambda e: e,
                            edges), None

        edges, _ = lax.scan(wire, edges, jnp.arange(r_ent))
        return dataclasses.replace(
            ent, ids=ids, edges=edges, count=ent.count + 1,
            main_to_ent=main_to_ent)

    return lax.cond(want, do_update, lambda e: e, ent)


def entrance_hop_stats(ent: EntranceGraph) -> dict:
    """Small diagnostics used by tests/benchmarks."""
    live = ent.ids >= 0
    deg = (ent.edges >= 0).sum(axis=1) * live
    return {"count": ent.count,
            "mean_degree": deg.sum() / jnp.maximum(live.sum(), 1)}
