"""Proximity-graph construction (Vamana-lineage) + quality helpers.

The base index is built *with the paper's own insertion machinery*: after a
small fully-connected bootstrap, vertices are added in random order in
blocks — each block position-seeks on a frozen snapshot (embarrassingly
parallel, like DiskANN's locked parallel build), is exact-reranked against
the in-memory build vectors, RobustPrune(α)-ed for diversity (close
neighbors + long-range shortcuts), and then wired sequentially through
:func:`insert.structural_update`.  One code path for build and runtime
updates means the invariants tested for inserts hold for the base index
too.

I/O during build is charged to a throwaway counter set (index construction
is offline; the paper measures it separately).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cache as cache_mod
from repro.core import insert as insert_mod
from repro.core import pq as pq_mod
from repro.core import search as search_mod
from repro.core.iomodel import IOCounters
from repro.core.layout import (GraphStore, LayoutSpec, assign_initial_pages,
                               empty_store)

INF = jnp.float32(3.4e38)


# ---------------------------------------------------------------------------
# Ground truth + recall
# ---------------------------------------------------------------------------

def brute_force_topk(queries: jax.Array, vectors: jax.Array,
                     n_live, k: int) -> jax.Array:
    """Exact top-k ids per query.  queries: [Q, D].

    ``n_live`` is either a count (considers the prefix ``[0, n_live)`` —
    fresh builds, where live vertices are contiguous) or a [N] bool mask
    (churned corpora: deletions punch holes in the prefix and reclaimed
    slots hold stale vectors, so the caller passes the exact live set).
    """
    vnorm = jnp.sum(vectors * vectors, axis=1)                 # [N]
    if getattr(n_live, "dtype", None) == jnp.bool_ and \
            getattr(n_live, "ndim", 0) == 1:
        live = n_live
    else:
        live = jnp.arange(vectors.shape[0]) < n_live

    def per_q(q):
        d = vnorm - 2.0 * (vectors @ q)                        # [N] (+‖q‖²)
        d = jnp.where(live, d, INF)
        _, idx = lax.top_k(-d, k)
        return idx.astype(jnp.int32)

    return jax.lax.map(per_q, queries, batch_size=64)


def recall_at_k(pred: jax.Array, truth: jax.Array) -> jax.Array:
    """Mean |pred ∩ truth| / k over queries.  pred, truth: [Q, k]."""
    hits = (pred[:, :, None] == truth[:, None, :]) & (truth[:, None, :] >= 0)
    return hits.any(axis=1).mean()


def medoid(vectors: jax.Array, n_live: int) -> jax.Array:
    """Vertex closest to the centroid of the live prefix."""
    live = vectors[:n_live]
    c = live.mean(axis=0)
    return jnp.argmin(jnp.sum((live - c) ** 2, axis=1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# RobustPrune (Vamana)
# ---------------------------------------------------------------------------

def robust_prune(q: jax.Array, cand_ids: jax.Array, cand_d: jax.Array,
                 vectors: jax.Array, *, alpha: float, r: int) -> jax.Array:
    """Diversity-pruned neighbor selection.

    Iteratively keeps the closest unpruned candidate p, then prunes every c
    with α·d(p,c) ≤ d(q,c) — c is better reached *through* p.  Returns [r]
    ids (-1 padded).  ``cand_d`` must be exact distances to q.
    """
    C = cand_ids.shape[0]
    safe = jnp.maximum(cand_ids, 0)
    cvecs = vectors[safe]                                       # [C, D]
    pruned = cand_ids < 0

    def step(carry, _):
        pruned = carry
        d_masked = jnp.where(pruned, INF, cand_d)
        best = jnp.argmin(d_masked)
        ok = d_masked[best] < INF
        kept_id = jnp.where(ok, cand_ids[best], -1)
        pvec = cvecs[best]
        d_pc = jnp.sum((cvecs - pvec[None]) ** 2, axis=1)       # [C]
        newly = ok & (alpha * d_pc <= cand_d)
        return pruned | newly, kept_id

    _, kept = lax.scan(step, pruned, None, length=r)
    return kept


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------

def bootstrap_store(vectors: jax.Array, spec: LayoutSpec, n_max: int,
                    n_boot: int) -> GraphStore:
    """Fully-connected clique over the first ``n_boot`` (≤ R+1) vectors."""
    store = empty_store(n_max, spec.dim, spec.r)
    idx = jnp.arange(n_boot, dtype=jnp.int32)
    # edges[i] = all j != i, padded to R
    all_ids = jnp.broadcast_to(idx, (n_boot, n_boot))
    mask = ~jnp.eye(n_boot, dtype=bool)
    # compact each row's neighbors to the front
    order = jnp.argsort(~mask, axis=1, stable=True)             # True first
    row = jnp.take_along_axis(jnp.where(mask, all_ids, -1), order, axis=1)
    edges = store.edges.at[:n_boot, :min(n_boot - 1, spec.r)].set(
        row[:, :min(n_boot - 1, spec.r)])
    store = dataclasses.replace(
        store,
        vectors=store.vectors.at[:n_boot].set(vectors[:n_boot]),
        edges=edges,
        degree=store.degree.at[:n_boot].set(
            min(n_boot - 1, spec.r)),
        count=jnp.asarray(n_boot, jnp.int32))
    return assign_initial_pages(store, spec)


@functools.partial(jax.jit, static_argnames=("spec", "e_pos", "alpha",
                                             "beam_width", "max_hops"))
def _build_block(store: GraphStore, spec: LayoutSpec, block_vecs: jax.Array,
                 codes: jax.Array, sym_tables: jax.Array,
                 codebooks: jax.Array, entry_ids: jax.Array, *,
                 e_pos: int, alpha: float, beam_width: int,
                 max_hops: int) -> GraphStore:
    """Insert one block: parallel position seeking on the snapshot, then
    sequential structural updates."""
    codec = pq_mod.PQCodec(codebooks)
    dummy_cache = cache_mod.init_cache(store.page_live.shape[0], 2, "none",
                                       jax.random.PRNGKey(0))

    def seek(q):
        lut = pq_mod.adc_lut(codec, q)
        res = search_mod.disk_traverse(
            store, spec, lut, codes, dummy_cache, IOCounters.zeros(),
            entry_ids, pool_size=e_pos, beam_width=beam_width,
            max_hops=max_hops)
        # exact rerank against the build vectors (offline: vectors on hand)
        valid = res.pool_ids >= 0
        d = jnp.where(valid, pq_mod.exact_l2(
            q, store.vectors[jnp.maximum(res.pool_ids, 0)]), INF)
        return robust_prune(q, res.pool_ids, d, store.vectors,
                            alpha=alpha, r=store.r)

    nbrs_all = jax.vmap(seek)(block_vecs)                      # [B, R]

    def wire(store, i):
        sres = insert_mod.structural_update(
            store, spec, dummy_cache, IOCounters.zeros(), block_vecs[i],
            nbrs_all[i], codes, sym_tables)
        return sres.store, None

    store, _ = lax.scan(wire, store, jnp.arange(block_vecs.shape[0]))
    return store


@functools.partial(jax.jit, static_argnames=("spec", "e_pos", "alpha",
                                             "beam_width", "max_hops"))
def _refine_block(store: GraphStore, spec: LayoutSpec, ids_block: jax.Array,
                  codes: jax.Array, codebooks: jax.Array,
                  entry_ids: jax.Array, *, e_pos: int, alpha: float,
                  beam_width: int, max_hops: int) -> GraphStore:
    """Second Vamana pass: re-seek each vertex on the finished graph,
    RobustPrune(pool ∪ current edges), replace its edgelist, and re-add
    reciprocal edges (replace-worst by exact distance — vectors are in
    memory at build time)."""
    codec = pq_mod.PQCodec(codebooks)
    dummy_cache = cache_mod.init_cache(store.page_live.shape[0], 2, "none",
                                       jax.random.PRNGKey(0))
    r = store.r
    n_max = store.n_max

    def reseek(i):
        q = store.vectors[i]
        lut = pq_mod.adc_lut(codec, q)
        res = search_mod.disk_traverse(
            store, spec, lut, codes, dummy_cache, IOCounters.zeros(),
            entry_ids, pool_size=e_pos, beam_width=beam_width,
            max_hops=max_hops)
        cand = jnp.concatenate([res.pool_ids, store.edges[i]])
        # dedupe (first occurrence) + exclude self
        arange = jnp.arange(cand.shape[0], dtype=jnp.int32)
        safe = jnp.maximum(cand, 0)
        first = jnp.full((n_max,), jnp.iinfo(jnp.int32).max, jnp.int32)
        first = first.at[safe].min(
            jnp.where(cand >= 0, arange, jnp.iinfo(jnp.int32).max))
        keep = (cand >= 0) & (cand != i) & (first[safe] == arange)
        cand = jnp.where(keep, cand, -1)
        d = jnp.where(keep, pq_mod.exact_l2(
            q, store.vectors[jnp.maximum(cand, 0)]), INF)
        return robust_prune(q, cand, d, store.vectors, alpha=alpha, r=r)

    new_edges = jax.vmap(reseek)(ids_block)                  # [B, r]

    # apply the replacement edgelists
    edges = store.edges.at[ids_block].set(new_edges)
    degree = store.degree.at[ids_block].set((new_edges >= 0).sum(axis=1))
    store = dataclasses.replace(store, edges=edges, degree=degree)

    # reciprocal wiring (flattened (vertex, nbr) pairs, exact distances)
    pairs_v = jnp.repeat(ids_block, r)
    pairs_j = new_edges.reshape(-1)

    def wire(carry, t):
        edges, degree = carry
        v, j = pairs_v[t], pairs_j[t]

        def do(args):
            edges, degree = args
            row = edges[j]
            present = (row == v).any()
            occupied = row >= 0
            free = jnp.argmin(occupied)
            has_free = ~occupied.all()
            jvec = store.vectors[j]
            d_row = jnp.where(occupied, pq_mod.exact_l2(
                jvec, store.vectors[jnp.maximum(row, 0)]), -INF)
            worst = jnp.argmax(d_row)
            d_v = jnp.sum((jvec - store.vectors[v]) ** 2)
            tgt = jnp.where(has_free, free, worst)
            write = (has_free | (d_v < d_row[worst])) & ~present
            new_row = jnp.where(write, row.at[tgt].set(v), row)
            new_deg = jnp.where(write & has_free, degree[j] + 1, degree[j])
            return edges.at[j].set(new_row), degree.at[j].set(new_deg)

        edges, degree = lax.cond((j >= 0) & (j != v), do, lambda a: a,
                                 (edges, degree))
        return (edges, degree), None

    (edges, degree), _ = lax.scan(wire, (edges, degree),
                                  jnp.arange(pairs_v.shape[0]))
    return dataclasses.replace(store, edges=edges, degree=degree)


def build_graph(key: jax.Array, vectors: jax.Array, n: int,
                spec: LayoutSpec, codec: pq_mod.PQCodec, codes: jax.Array,
                *, n_max: int | None = None, e_pos: int = 64,
                alpha: float = 1.2, block: int = 64, beam_width: int = 4,
                max_hops: int = 128, n_entry: int = 4,
                refine: bool = True) -> GraphStore:
    """Build the base index over ``vectors[:n]``.

    Two passes, as Vamana prescribes: an incremental insertion pass at
    α=1.0 (cheap, but early vertices see a poor partial graph), then a
    refinement pass at α (default 1.2) that re-seeks every vertex on the
    finished graph and rebuilds its edgelist — this is what makes the graph
    navigable.  ``codes`` must already hold the PQ encodings of ``vectors``
    (the engine trains/encodes once and shares them with the runtime).
    """
    n_max = n_max or vectors.shape[0]
    sym_tables = pq_mod.sym_tables(codec)
    n_boot = min(spec.r + 1, n)
    store = bootstrap_store(vectors, spec, n_max, n_boot)
    entry_ids = jnp.arange(n_entry, dtype=jnp.int32) % n_boot

    pos = n_boot
    while pos < n:
        b = min(block, n - pos)
        block_vecs = vectors[pos:pos + b]
        if b < block:   # pad to the jitted block shape; wire only b of them
            block_vecs = jnp.pad(block_vecs, ((0, block - b), (0, 0)))
        store_full = _build_block(
            store, spec, block_vecs, codes, sym_tables, codec.codebooks,
            entry_ids, e_pos=e_pos, alpha=1.0, beam_width=beam_width,
            max_hops=max_hops)
        if b < block:
            # roll back the padded tail inserts (zero vectors)
            store = _truncate(store_full, pos + b)
        else:
            store = store_full
        pos += b

    if refine and n > n_boot:
        order = jax.random.permutation(key, n).astype(jnp.int32)
        for start in range(0, n, block):
            ids_block = order[start:start + block]
            if ids_block.shape[0] < block:
                ids_block = jnp.pad(ids_block, (0, block - ids_block.shape[0]),
                                    constant_values=ids_block[0])
            store = _refine_block(
                store, spec, ids_block, codes, codec.codebooks, entry_ids,
                e_pos=e_pos, alpha=alpha, beam_width=beam_width,
                max_hops=max_hops)
    return store


def _truncate(store: GraphStore, n_keep: int) -> GraphStore:
    """Drop vertices ≥ n_keep added by a padded block (host-side, rare)."""
    import numpy as np
    edges = np.asarray(store.edges).copy()
    degree = np.asarray(store.degree).copy()
    edge_page = np.asarray(store.edge_page).copy()
    page_live = np.asarray(store.page_live).copy()
    mask = edges >= n_keep
    degree = degree - mask.sum(axis=1)
    edges = np.where(mask, -1, edges)
    edges[n_keep:] = -1
    degree[n_keep:] = 0
    # give the dropped rows' page slots back: a phantom live count would
    # suppress the dead-page eviction hints downstream (§8.2, repair)
    dropped_pages = edge_page[n_keep:]
    np.subtract.at(page_live, dropped_pages[dropped_pages >= 0], 1)
    edge_page[n_keep:] = -1
    return dataclasses.replace(
        store, edges=jnp.asarray(edges), degree=jnp.asarray(degree),
        edge_page=jnp.asarray(edge_page),
        page_live=jnp.asarray(page_live),
        count=jnp.asarray(n_keep, jnp.int32))


# ---------------------------------------------------------------------------
# Graph invariants (tested; also used as a runtime sanity hook)
# ---------------------------------------------------------------------------

def check_invariants(store: GraphStore,
                     tombstone: jax.Array | None = None) -> dict:
    """Pure-jnp invariant summary: all must hold for a well-formed graph.

    With ``tombstone`` supplied, additionally checks the post-consolidation
    contract: no live vertex's edgelist references a tombstoned vertex
    (the maintenance repair pass spliced every dead pointer away).
    """
    n = store.count
    live = jnp.arange(store.n_max) < n
    edges = store.edges
    valid_edges = edges >= 0
    deg = valid_edges.sum(axis=1)
    in_range = jnp.where(valid_edges, edges < n, True).all()
    no_self = jnp.where(
        valid_edges, edges != jnp.arange(store.n_max)[:, None], True).all()
    deg_ok = (jnp.where(live, deg <= store.r, True)).all()
    deg_matches = (jnp.where(live, deg == store.degree, True)).all()
    dead_clean = (~live[:, None] | valid_edges | (edges == -1)).all()
    out = {"edges_in_range": in_range, "no_self_loops": no_self,
           "degree_le_r": deg_ok, "degree_field_consistent": deg_matches,
           "padding_clean": dead_clean}
    if tombstone is not None:
        row_live = live & ~tombstone
        out["no_dead_refs"] = jnp.where(
            row_live[:, None] & valid_edges,
            ~tombstone[jnp.maximum(edges, 0)], True).all()
    return out
