"""NAVIS core: the paper's contribution as a composable JAX system.

Public API:
    EngineSpec / Engine / preset / PRESETS      (engine.py)
    GraphStore / LayoutSpec                     (layout.py)
    build_graph / brute_force_topk / recall_at_k (graph.py)
    SSDModel / HBMModel / IOCounters            (iomodel.py)
    Engine.consolidate / maintenance_step /
        needs_consolidation                     (engine.py + maintenance.py)
"""
from repro.core.engine import (Engine, EngineSpec, EngineState, OpStats,
                               PRESETS, preset)
from repro.core.graph import (brute_force_topk, build_graph, check_invariants,
                              medoid, recall_at_k, robust_prune)
from repro.core.iomodel import (HBMModel, IOCounters, PAGE_BYTES, SSDModel,
                                merge_counters, sum_counters)
from repro.core.layout import GraphStore, LayoutSpec, empty_store

__all__ = [
    "Engine", "EngineSpec", "EngineState", "OpStats", "PRESETS", "preset",
    "brute_force_topk", "build_graph", "check_invariants", "medoid",
    "recall_at_k", "robust_prune", "HBMModel", "IOCounters", "PAGE_BYTES",
    "SSDModel", "GraphStore", "LayoutSpec", "empty_store",
    "merge_counters", "sum_counters",
]
