"""Two-tier I/O accounting + device cost models.

The paper's SSD is our *slow tier*; the TPU adaptation maps it onto
HBM-behind-a-gather (or host DRAM over PCIe for beyond-HBM corpora) — see
DESIGN.md §2.  Every traversal / rerank / structural-update primitive threads
an :class:`IOCounters` pytree through, so benchmarks read exact per-category
byte and request counts; the cost models convert them into time (the paper's
throughput/latency figures) without needing the physical device.

Categories follow Fig. 4(a): useful vector, wasted vector, edgelist, padding,
for both reads and writes, all at 4 KiB page granularity.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

PAGE_BYTES = 4096


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IOCounters:
    """Per-category I/O tallies (device arrays so they live inside jit)."""

    read_requests: jax.Array
    write_requests: jax.Array
    edge_bytes_read: jax.Array
    useful_vec_bytes_read: jax.Array
    wasted_vec_bytes_read: jax.Array
    pad_bytes_read: jax.Array
    edge_bytes_written: jax.Array
    vec_bytes_written: jax.Array
    wasted_vec_bytes_written: jax.Array   # packed: co-written neighbor vecs
    pad_bytes_written: jax.Array
    cache_hits: jax.Array
    cache_misses: jax.Array
    hops: jax.Array
    # hashed-visited-set saturation events (impossible at default capacity;
    # a saturated traversal may re-expand vertices, re-charging I/O only)
    visited_overflow: jax.Array
    # explored-pool slots wasted on tombstoned vertices (the traversal
    # scored/loaded them, the result mask threw them away) — the churn
    # benchmarks read this to quantify pre-consolidation degradation
    tombstone_skips: jax.Array

    @classmethod
    def zeros(cls) -> "IOCounters":
        z = lambda: jnp.zeros((), jnp.int64)
        return cls(*[z() for _ in dataclasses.fields(cls)])

    def total_read_bytes(self):
        return (self.edge_bytes_read + self.useful_vec_bytes_read +
                self.wasted_vec_bytes_read + self.pad_bytes_read)

    def total_write_bytes(self):
        return (self.edge_bytes_written + self.vec_bytes_written +
                self.wasted_vec_bytes_written + self.pad_bytes_written)

    def asdict(self) -> dict:
        return {f.name: int(getattr(self, f.name))
                for f in dataclasses.fields(self)}


def merge_counters(a: IOCounters, b: IOCounters) -> IOCounters:
    return jax.tree.map(lambda x, y: x + y, a, b)


def sum_counters(batched: IOCounters) -> IOCounters:
    """Reduce per-query counters ([Q]-leading leaves, e.g. from a vmapped
    search fan-out) to one scalar tally.  Concurrent readers charge I/O
    independently; the device serves the union, so counts simply add."""
    return jax.tree.map(lambda x: x.sum(axis=0), batched)


@dataclasses.dataclass(frozen=True)
class SSDModel:
    """NVMe cost model (defaults ≈ the paper's Crucial T705 PCIe 5.0).

    time = max(request-bound, bandwidth-bound) under a given queue depth;
    per-request latency contributes to *latency* metrics, throughput uses the
    steady-state bound.
    """

    read_iops: float = 1.40e6          # 4 KiB random read IOPS
    write_iops: float = 1.10e6
    read_bw: float = 13.6e9            # B/s sequential
    write_bw: float = 12.0e9
    request_latency: float = 55e-6     # s, single 4 KiB random read
    queue_depth: int = 256

    def read_time(self, requests: float, bytes_: float) -> float:
        return max(requests / self.read_iops, bytes_ / self.read_bw)

    def write_time(self, requests: float, bytes_: float) -> float:
        return max(requests / self.write_iops, bytes_ / self.write_bw)

    def op_latency(self, requests: float, bytes_: float,
                   serial_rounds: float) -> float:
        """Latency of one logical op whose I/O happens in ``serial_rounds``
        dependent rounds (graph hops are serial; intra-round I/O overlaps)."""
        return (serial_rounds * self.request_latency
                + self.read_time(requests, bytes_))


@dataclasses.dataclass(frozen=True)
class HBMModel:
    """TPU slow-tier analogue: gathers from HBM (819 GB/s, v5e).

    The per-request term models gather descriptor overhead — tiny, but keeps
    CASR's request-count-vs-bytes tradeoff meaningful on-TPU (DESIGN.md §2).
    """

    bw: float = 819e9
    request_latency: float = 1e-6
    read_iops: float = 50e6
    write_iops: float = 50e6
    read_bw: float = 819e9
    write_bw: float = 819e9
    queue_depth: int = 1024

    def read_time(self, requests, bytes_):
        return max(requests / self.read_iops, bytes_ / self.read_bw)

    def write_time(self, requests, bytes_):
        return max(requests / self.write_iops, bytes_ / self.write_bw)

    def op_latency(self, requests, bytes_, serial_rounds):
        return (serial_rounds * self.request_latency
                + self.read_time(requests, bytes_))
