"""Serving launcher: batched prefill + decode on any assigned arch.

``python -m repro.launch.serve --arch gemma-2b --prompt-len 64 --gen 32``

Runs the smoke (reduced) config on CPU: prefill the prompt batch, then
greedy-decode ``--gen`` tokens with the KV/SSM cache, reporting per-phase
latency and tokens/s — the same serve_step the dry-run lowers at full
scale.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.models import transformer as T
from repro.train.serve_step import make_decode_step, make_prefill_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(C.ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = C.get_arch(args.arch)
    cfg = arch.smoke
    max_seq = args.prompt_len + args.gen

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)
    cross = None
    if cfg.cross_seq:
        cross = jax.random.normal(
            key, (args.batch, cfg.cross_seq, cfg.d_model)).astype(cfg.dtype)

    prefill = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, tokens, cross) if cross is not None \
        else prefill(params, tokens)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill[{args.batch}x{args.prompt_len}]: {t_prefill:.2f}s")

    cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [cur]
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        cur, logits, cache = decode(params, cache, cur, pos)
        cur = cur[:, None]
        out.append(cur)
    jax.block_until_ready(cur)
    t_dec = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode {args.gen} steps: {t_dec:.2f}s "
          f"({args.gen * args.batch / max(t_dec, 1e-9):.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())
    assert not jnp.isnan(logits).any(), "NaN logits"
    return 0


if __name__ == "__main__":
    sys.exit(main())
