"""Training launcher: ``python -m repro.launch.train --arch qwen2-0.5b``.

Production-shaped loop on whatever hardware is present (CPU container:
reduced configs; TPU pod: full configs + production mesh):

* resume-from-latest checkpoint (atomic commits, see checkpoint/store.py)
* elastic remesh: ``--remesh`` restores a checkpoint saved under a
  different mesh shape by re-device_put-ing every leaf
* straggler/failure handling: batch generation and the step itself are
  retried up to ``--max-retries`` with the same (step, shard) inputs
  (the data pipeline is stateless so retries are bit-identical);
  a persistently failing step is skipped and logged — the loss masks it
* heartbeat: a JSON line per step (step, loss, t_step, tokens/s) to stdout
  and ``<ckpt>/heartbeat.jsonl``; stalls are visible to any watchdog
* ``--crash-at N`` injects a hard failure at step N (restart drills for
  tests/examples)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs as C
from repro import checkpoint as ckpt_mod
from repro.data import TokenStream
from repro.launch import mesh as M
from repro.models import transformer as T
from repro.train import optimizer as O
from repro.train.train_step import make_train_step


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(C.ARCH_IDS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (the CPU default)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--remesh", action="store_true",
                    help="restore onto the current mesh regardless of the "
                         "mesh the checkpoint was saved under")
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--crash-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    arch = C.get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.model

    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         batch=args.batch, seed=args.seed)
    opt = O.make_optimizer(arch.optimizer, lr=O.cosine_schedule(
        args.lr, warmup=min(20, args.steps // 10 + 1), total=args.steps))
    step_fn = jax.jit(make_train_step(
        cfg, opt, microbatches=args.microbatches,
        grad_compression=args.grad_compression), donate_argnums=(0, 1))

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    from repro.train.train_step import init_opt_state
    opt_state = init_opt_state(cfg, opt, params,
                               grad_compression=args.grad_compression)

    start = 0
    hb_file = None
    if args.ckpt:
        ckpt_dir = Path(args.ckpt)
        state_like = {"params": params, "opt": opt_state}
        step0, restored = ckpt_mod.load_latest(ckpt_dir, state_like)
        if step0 is not None:
            params, opt_state = restored["params"], restored["opt"]
            start = step0 + 1
            print(f"resumed from step {step0}", flush=True)
        hb_file = (ckpt_dir / "heartbeat.jsonl")
        ckpt_dir.mkdir(parents=True, exist_ok=True)

    tokens_per_step = args.batch * args.seq
    for step in range(start, args.steps):
        if step == args.crash_at:
            print(f"CRASH injected at step {step}", flush=True)
            sys.stdout.flush()
            import os
            os._exit(42)

        t0 = time.time()
        loss = None
        for attempt in range(args.max_retries + 1):
            try:
                batch = stream.make_batch(step)          # idempotent
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch, jnp.asarray(step, jnp.int32))
                loss = float(metrics["loss"])
                break
            except Exception as e:                       # noqa: BLE001
                print(f"step {step} attempt {attempt} failed: "
                      f"{type(e).__name__}: {e}", flush=True)
                if attempt == args.max_retries:
                    print(f"step {step} SKIPPED after retries", flush=True)
        dt = time.time() - t0

        if loss is not None and (step % args.log_every == 0
                                 or step == args.steps - 1):
            hb = {"step": step, "loss": round(loss, 4),
                  "t_step_s": round(dt, 3),
                  "tokens_per_s": round(tokens_per_step / max(dt, 1e-9))}
            line = json.dumps(hb)
            print(line, flush=True)
            if hb_file is not None:
                with open(hb_file, "a") as f:
                    f.write(line + "\n")

        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt_mod.save(args.ckpt, step,
                          {"params": params, "opt": opt_state})
    if args.ckpt:
        ckpt_mod.save(args.ckpt, args.steps - 1,
                      {"params": params, "opt": opt_state})
    return 0


if __name__ == "__main__":
    sys.exit(main())
