"""Post-SPMD HLO analysis: FLOPs, HBM traffic and collective bytes with
while-loop trip-count multipliers.

XLA's ``compiled.cost_analysis()`` counts a while body's cost ONCE, so a
scan-over-layers model under-reports FLOPs/bytes by ~num_layers.  The
roofline needs per-step totals, so this module parses the optimized HLO
text instead:

* computations are split and a call-graph multiplier is computed for each
  (while bodies multiply by the trip count inferred from their condition's
  compare constant; fusions/calls carry ×1),
* **FLOPs**: every ``dot`` contributes 2·|out|·|contracting| × multiplier,
* **HBM traffic**: instructions of *control* computations (ENTRY, while
  bodies/conds — i.e. not fused subcomputations) contribute operand +
  output bytes × multiplier; bookkeeping ops (tuple plumbing, parameters,
  constants, bitcasts) are skipped.  Fusion-internal ops never touch HBM,
  so only fusion boundaries count — matching how XLA:TPU schedules them,
* **collective bytes**: operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute × multiplier
  (all-reduce wires ~2× its payload on a ring).

All numbers are per-device (the HLO module is the partitioned program).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng",
    "get-dimension-size",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "%name = SHAPE opname(rest" — SHAPE may be a tuple containing layout
# braces and /*index=N*/ comments, so match it non-greedily up to the last
# lowercase-op-token-followed-by-( pattern.
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([a-z][\w\-]*)\((.*)$")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    defs: dict            # name -> shape string


def split_computations(hlo: str) -> dict[str, "Computation"]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            m = re.search(r"%?([\w\.\-]+)\s*\(", s)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if s == "}":
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if dm:
            name, shape, op, rest = dm.groups()
            cur.instrs.append(Instr(name, shape, op, rest))
            cur.defs[name] = shape
        # parameters appear as "%p = f32[...] parameter(0)" (matched above)
    return comps


def _trip_counts(comps: dict) -> dict[str, int]:
    """while body computation name -> trip count (max cond constant)."""
    body_cond = {}
    for c in comps.values():
        for ins in c.instrs:
            if ins.op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                if bm and cm:
                    body_cond[bm.group(1)] = cm.group(1)
    trips = {}
    for body, cond in body_cond.items():
        consts = []
        for ins in comps.get(cond, Computation("", [], {})).instrs:
            if ins.op == "constant":
                m = re.match(r"\s*(\d+)\)", ins.rest)
                if m:
                    consts.append(int(m.group(1)))
            consts += [int(x) for x in
                       re.findall(r"constant\((\d+)\)", ins.rest)]
        trips[body] = max(consts) if consts else 1
    return trips


_REF_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations=\{)"
    r"[=]?%?([\w\.\-]+)")


def _multipliers(comps: dict, trips: dict) -> dict[str, int]:
    parents: dict[str, list[tuple[str, int]]] = {}
    called_via_calls: set[str] = set()
    for cname, c in comps.items():
        for ins in c.instrs:
            for attr, ref in re.findall(
                    r"(calls|to_apply|body|condition)=%?([\w\.\-]+)",
                    ins.rest):
                mult = trips.get(ref, 1) if attr == "body" else 1
                parents.setdefault(ref, []).append((cname, mult))
                if attr in ("calls", "to_apply"):
                    called_via_calls.add(ref)
            bm = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
            if bm:
                for ref in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                    parents.setdefault(ref, []).append((cname, 1))
                    called_via_calls.add(ref)

    cache: dict[str, int] = {}

    def mult(comp: str, depth=0) -> int:
        if depth > 40:
            return 1
        if comp in cache:
            return cache[comp]
        ps = parents.get(comp)
        if not ps:
            cache[comp] = 1
            return 1
        total = sum(m * mult(par, depth + 1) for par, m in ps)
        cache[comp] = max(total, 1)
        return cache[comp]

    out = {c: mult(c) for c in comps}
    out["__fused__"] = sorted(called_via_calls)  # type: ignore
    return out


def analyze(hlo: str, *, bf16_collectives: bool | None = None) -> dict:
    comps = split_computations(hlo)
    trips = _trip_counts(comps)
    mults = _multipliers(comps, trips)
    fused = set(mults.pop("__fused__"))  # computations inlined by a caller

    # is this a bf16 model?  (drives the collective dtype rule; callers
    # that know the config dtype pass it explicitly)
    if bf16_collectives is None:
        n_bf16 = hlo.count("bf16[")
        n_f32 = hlo.count("f32[")
        bf16_collectives = n_bf16 > 0.2 * (n_bf16 + n_f32)
    _bf16_module = bf16_collectives

    dot_flops = 0.0
    traffic = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0 for k in _COLLECTIVES}

    def _collective_scale(ins, c) -> float:
        """TPU dtype correction for collectives.

        XLA:CPU has no native bf16 matmul: its float-normalization pass
        upcasts every bf16 dot's operands/outputs to f32, and the
        algebraic simplifier hoists those converts across the
        SPMD-placed all-gathers/all-reduces — so the CPU-compiled HLO
        moves f32 activations where a TPU compilation (native bf16 MXU;
        converts sink into the dot) moves bf16.  Rule: in a bf16-dominant
        module, any ≥1 MiB f32 collective is counted at bf16 width.
        Small f32 collectives (loss scalars, norms) are left alone.
        """
        shapes = _SHAPE_RE.findall(ins.shape)
        if not shapes:
            return 1.0
        if all(dt == "f32" for dt, _ in shapes) and \
                _shape_bytes(ins.shape) >= (1 << 20) and _bf16_module:
            return 0.5
        return 1.0

    def _fusion_sub(ins):
        fm = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
        if fm and fm.group(1) in comps:
            return comps[fm.group(1)]
        return None

    def _instr_traffic(ins, defs) -> float:
        """HBM bytes of one control-flow instruction.

        * dynamic-update-slice (incl. fusions rooted in one, possibly
          convert-wrapped) is in-place on TPU: write the update slice,
          don't re-read the aliased buffer.
        * dynamic-slice reads only the slice it produces; a fusion
          parameter that is consumed *only through dynamic-slice* inside
          the fusion contributes the slice bytes, not the buffer bytes
          (scan bodies slice their layer out of stacked weight/cache
          arrays — charging the full stack per layer is a 48× overcount).
        """
        out_b = _shape_bytes(ins.shape)
        refs = re.findall(r"%([\w\.\-]+)", ins.rest)[:10]
        if ins.op == "dynamic-slice":
            return 2.0 * out_b
        if ins.op == "dynamic-update-slice":
            upd = _shape_bytes(defs.get(refs[1], "")) if len(refs) > 1 else 0
            others = sum(_shape_bytes(defs.get(r2, "")) for r2 in refs[2:])
            return float(2 * upd + others)
        if ins.op != "fusion":
            b = float(out_b)
            for r2 in refs:
                if r2 in defs:
                    b += _shape_bytes(defs[r2])
            return b

        sub = _fusion_sub(ins)
        if sub is None or not sub.instrs:
            return float(out_b)
        # root DUS (optionally behind converts): output is an in-place
        # update — count the update slice, not the buffer
        root = sub.instrs[-1]
        seen = 0
        while root.op == "convert" and seen < 3:
            tgt = re.findall(r"%([\w\.\-]+)", root.rest)
            nxt = next((i for i in sub.instrs if i.name == (
                tgt[0] if tgt else "")), None)
            if nxt is None:
                break
            root, seen = nxt, seen + 1
        if root.op == "dynamic-update-slice":
            dus_refs = re.findall(r"%([\w\.\-]+)", root.rest)
            upd = _shape_bytes(sub.defs.get(dus_refs[1], "")) \
                if len(dus_refs) > 1 else 0
            out_b = 2 * upd
        # parameters consumed only via dynamic-slice count at slice size
        param_of = {}                       # sub param index -> global ref
        for k, i2 in enumerate(sub.instrs):
            if i2.op == "parameter":
                m2 = re.match(r"\s*(\d+)\)", i2.rest)
                if m2:
                    param_of[i2.name] = int(m2.group(1))
        sliced_params = {}
        used_elsewhere = set()
        for i2 in sub.instrs:
            rr = re.findall(r"%([\w\.\-]+)", i2.rest)
            for r2 in rr:
                if r2 in param_of:
                    if i2.op == "dynamic-slice" and rr and rr[0] == r2:
                        sliced_params.setdefault(
                            r2, 0)
                        sliced_params[r2] += _shape_bytes(i2.shape)
                    else:
                        used_elsewhere.add(r2)
        b = float(out_b)
        for pname, idx in param_of.items():
            if idx >= len(refs):
                continue
            gref = refs[idx]
            if gref not in defs:
                continue
            if pname in sliced_params and pname not in used_elsewhere:
                b += sliced_params[pname]
            else:
                b += _shape_bytes(defs[gref])
        return b

    for cname, c in comps.items():
        m = mults.get(cname, 1)
        control = cname not in fused
        for ins in c.instrs:
            if ins.op == "dot":
                out_elems = math.prod(_shape_dims(ins.shape) or [1])
                lhs_m = re.match(r"\s*%?([\w\.\-]+)", ins.rest)
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                               ins.rest)
                if lhs_m and cm and lhs_m.group(1) in c.defs:
                    ldims = _shape_dims(c.defs[lhs_m.group(1)])
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            contract *= ldims[int(ci)]
                dot_flops += 2.0 * out_elems * contract * m
            for kind in _COLLECTIVES:
                if ins.op == kind or ins.op.startswith(kind + "-start"):
                    b = _shape_bytes(ins.shape) * _collective_scale(ins, c)
                    wire = 2 * b if kind == "all-reduce" else b
                    coll[kind] += wire * m
                    coll_counts[kind] += m
                    break
            if control and ins.op not in _SKIP_OPS and ins.op != "while":
                traffic += _instr_traffic(ins, c.defs) * m

    coll["total"] = sum(coll[k] for k in _COLLECTIVES)
    return {
        "dot_flops": dot_flops,
        "hbm_traffic_bytes": traffic,
        "collectives": {"bytes_by_kind": coll, "op_counts": coll_counts},
        "n_computations": len(comps),
        "max_trip": max(trips.values()) if trips else 1,
    }


def top_contributors(hlo: str, *, kind: str = "traffic",
                     n: int = 20) -> list[tuple[float, str]]:
    """Largest per-instruction contributors (bytes or collective bytes),
    with op metadata so the source line is identifiable.  The hillclimb's
    'profile' — run on a dry-run cell's dumped HLO."""
    comps = split_computations(hlo)
    trips = _trip_counts(comps)
    mults = _multipliers(comps, trips)
    fused = set(mults.pop("__fused__"))

    def _fusion_root(ins):
        fm = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
        if fm and fm.group(1) in comps:
            sub = comps[fm.group(1)]
            return sub.instrs[-1] if sub.instrs else None
        return None

    out = []
    for cname, c in comps.items():
        m = mults.get(cname, 1)
        control = cname not in fused
        for ins in c.instrs:
            meta = re.search(r'op_name="([^"]*)"', ins.rest)
            label = (meta.group(1)[:90] if meta else ins.name[:60])
            if kind == "collective":
                for ck in _COLLECTIVES:
                    if ins.op == ck or ins.op.startswith(ck + "-start"):
                        b = _shape_bytes(ins.shape)
                        wire = 2 * b if ck == "all-reduce" else b
                        out.append((wire * m, f"{ck} x{m} {ins.shape[:48]} "
                                    f"{label}"))
                        break
                continue
            if not control or ins.op in _SKIP_OPS or ins.op == "while":
                continue
            out_b = _shape_bytes(ins.shape)
            refs = re.findall(r"%([\w\.\-]+)", ins.rest)[:8]
            if ins.op == "dynamic-slice":
                b = 2.0 * out_b
            else:
                root = _fusion_root(ins) if ins.op == "fusion" else None
                if ins.op == "dynamic-update-slice" or (
                        root is not None
                        and root.op == "dynamic-update-slice"):
                    continue   # in-place; negligible after the DUS rule
                b = float(out_b)
                for r2 in refs:
                    if r2 in c.defs:
                        b += _shape_bytes(c.defs[r2])
            out.append((b * m, f"{ins.op} x{m} {ins.shape[:48]} {label}"))
    out.sort(reverse=True)
    return out[:n]
