"""Production mesh construction + sharding-rule derivation.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import ShardingRules


def _axis_type_kwargs(n: int) -> dict:
    # jax.sharding.AxisType (and make_mesh's axis_types=) only exist on
    # newer JAX; on 0.4.x every axis is Auto anyway, so omit the kwarg.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh(shape, axes):
    """`jax.make_mesh` with explicit-Auto axes where the API supports it."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_smoke_mesh():
    """1×1 mesh with the production axis names, for single-host tests."""
    return make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_size(mesh) -> int:
    return math.prod(mesh.shape[a] for a in batch_axes(mesh))


def make_rules(mesh, *, kind: str, global_batch: int,
               cfg=None) -> ShardingRules:
    """Sharding rules for one (shape-kind, batch) cell on a mesh.

    train/prefill: batch over (pod, data), TP over model, FSDP over data.
    decode: batch over (pod, data), KV-cache sequence over model
            (flash-decode style; softmax over the sharded axis is partial-
            reduced by GSPMD).
    batch=1 (long_500k): nothing batch-shards; long sequence/state dims
            spread over every mesh axis instead.
    """
    baxes = batch_axes(mesh)
    dsize = data_size(mesh)
    if global_batch >= dsize and global_batch % dsize == 0:
        b = baxes if len(baxes) > 1 else baxes[0]
    else:
        b = None
    if kind in ("train", "prefill"):
        # seq-parallel attention (§Perf, llama cell): on when gathering the
        # KV heads costs at most half of gathering the residual
        import os
        sp = os.environ.get("REPRO_SP_ATTN", "") == "1"
        if cfg is not None and getattr(cfg, "num_heads", 0):
            sp = sp or (cfg.num_kv_heads * cfg.hd * 2 <= cfg.d_model)
        return ShardingRules(batch=b, tensor="model", fsdp="data", seq=None,
                             act_seq="model", seq_parallel_attn=sp)
    # decode: MoE weights stay 2-D sharded — the per-token FSDP weight
    # gather is the dominant roofline term otherwise (§Perf, moonshot cell)
    if b is None:
        seq = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    else:
        seq = "model"
    return ShardingRules(batch=b, tensor="model", fsdp="data", seq=seq,
                         moe_gather_weights=False)


def named(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_specs(mesh, rules: ShardingRules, input_tree):
    """Sharding specs for step-fn data inputs (tokens / cross_src / pos)."""
    def spec(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "pos":
            return P()
        return P(rules.batch, *([None] * (len(x.shape) - 1)))
    return jax.tree_util.tree_map_with_path(spec, input_tree)
