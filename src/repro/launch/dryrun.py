import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init).  Everything below is ordinary code.
#
# Multi-pod dry-run: lower + compile every (arch × shape) cell on the
# production meshes and extract memory / FLOP / collective-byte analyses.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
#   PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs as C
from repro.launch import mesh as M
from repro.models import transformer as T
from repro.train import optimizer as O
from repro.train.train_step import make_train_step
from repro.train.serve_step import make_decode_step, make_prefill_step

# ---------------------------------------------------------------------------
# HLO collective-traffic parser
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[16,128]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device collective bytes from post-SPMD HLO text.

    Collectives inside while bodies (scanned layers) are multiplied by the
    loop trip count, inferred from the largest integer constant in the loop
    condition computation.  Returns totals by kind plus per-kind op counts.
    """
    # 1. split into computations
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{",
                     line)
        if ("{" in line and ("->" in line or line.strip().startswith("ENTRY"))
                and not line.strip().startswith("//")):
            m2 = re.search(r"%?([\w\.\-]+)\s*\(", line)
            if m2:
                cur = m2.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)

    # 2. trip count per while body: map body-comp -> count
    body_trip: dict[str, int] = {}
    cond_of_body: dict[str, str] = {}
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" in ln or "=while(" in ln:
                bm = re.search(r"body=%?([\w\.\-]+)", ln)
                cm = re.search(r"condition=%?([\w\.\-]+)", ln)
                if bm and cm:
                    cond_of_body[bm.group(1)] = cm.group(1)
    for body, cond in cond_of_body.items():
        consts = [int(x) for ln in comps.get(cond, ())
                  for x in re.findall(r"constant\((\d+)\)", ln)]
        body_trip[body] = max(consts) if consts else 1

    # 3. call-graph multipliers (while bodies multiply; calls/fusions carry 1x)
    parents: dict[str, list[tuple[str, int]]] = {}
    for cname, lines in comps.items():
        for ln in lines:
            for ref in re.findall(
                    r"(?:body|to_apply|calls)=%?([\w\.\-]+)", ln):
                mult = body_trip.get(ref, 1) if f"body=%{ref}" in ln or \
                    f"body={ref}" in ln else 1
                parents.setdefault(ref, []).append((cname, mult))

    mult_cache: dict[str, int] = {}

    def multiplier(comp: str, depth=0) -> int:
        if depth > 20:
            return 1
        if comp in mult_cache:
            return mult_cache[comp]
        ps = parents.get(comp)
        if not ps:
            mult_cache[comp] = 1
            return 1
        total = 0
        for parent, m in ps:
            total += m * multiplier(parent, depth + 1)
        mult_cache[comp] = max(total, 1)
        return mult_cache[comp]

    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for cname, lines in comps.items():
        mult = multiplier(cname)
        for ln in lines:
            for kind in _COLLECTIVES:
                if re.search(rf"=\s*[\w\[\],\(\) ]*{kind}\(", ln) or \
                        f" {kind}(" in ln:
                    lhs = ln.split("=")[0] if "=" in ln else ""
                    shape_src = ln.split("=", 1)[1] if "=" in ln else ln
                    head = shape_src.strip().split(kind)[0]
                    b = _shape_bytes(head)
                    # all-reduce moves ~2x its payload on a ring; others ~1x
                    wire = 2 * b if kind == "all-reduce" else b
                    totals[kind] += wire * mult
                    counts[kind] += mult
                    break
    totals["total"] = sum(totals[k] for k in _COLLECTIVES)
    return {"bytes_by_kind": totals, "op_counts": counts}


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh, *, smoke: bool = False):
    """Returns (jitted_fn, arg_shapes:list, donate) ready to .lower()."""
    arch = C.get_arch(arch_id)
    shape = C.SHAPES[shape_name]
    cfg = arch.smoke if smoke else arch.model
    rules = M.make_rules(mesh, kind=shape.kind,
                         global_batch=shape.global_batch, cfg=cfg)
    pspecs = T.param_specs(cfg)
    pshapes = T.param_shapes(cfg)
    psh = M.named(mesh, pspecs)
    specs = C.input_specs(arch, shape, smoke=smoke, rules=rules)

    if shape.kind == "train":
        opt = O.make_optimizer(arch.optimizer, state_dtype=arch.opt_state_dtype)
        step_fn = make_train_step(cfg, opt, rules=rules, mesh=mesh)
        opt_shapes = jax.eval_shape(opt.init, pshapes)
        opt_specs = opt.init_specs(pspecs, pshapes)
        osh = M.named(mesh, opt_specs)
        batch = {k: v for k, v in specs.items()}
        bsh = M.named(mesh, M.batch_specs(mesh, rules, batch))
        fn = jax.jit(step_fn,
                     in_shardings=(psh, osh, bsh, None),
                     donate_argnums=(0, 1))
        args = (pshapes, opt_shapes, batch,
                jax.ShapeDtypeStruct((), jnp.int32))
        return fn, args

    if shape.kind == "prefill":
        step_fn = make_prefill_step(cfg, rules=rules, mesh=mesh)
        tokens = specs["tokens"]
        cross = specs.get("cross_src")
        tsh = M.named(mesh, M.batch_specs(mesh, rules, {"tokens": tokens}))[
            "tokens"]
        if cross is not None:
            csh = M.named(mesh, P(rules.batch, None, None))
            fn = jax.jit(step_fn, in_shardings=(psh, tsh, csh))
            return fn, (pshapes, tokens, cross)
        fn = jax.jit(lambda p, t: step_fn(p, t), in_shardings=(psh, tsh))
        return fn, (pshapes, tokens)

    # decode
    step_fn = make_decode_step(cfg, rules=rules, mesh=mesh)
    cache_sp = T.cache_specs(cfg, shape.global_batch, shape.seq_len, rules)
    csh = M.named(mesh, cache_sp)
    tokens = specs["tokens"]
    tsh = M.named(mesh, P(rules.batch, None))
    fn = jax.jit(lambda p, c, t, pos: step_fn(p, c, t, pos),
                 in_shardings=(psh, csh, tsh, None),
                 donate_argnums=(1,))
    return fn, (pshapes, specs["cache"], tokens, specs["pos"])


def _sharded_bytes(shapes_tree, specs_tree, mesh) -> int:
    """Per-device bytes of a sharded pytree (leaf nbytes / shard count)."""
    sizes = dict(mesh.shape)

    def leaf(sh, sp):
        n = 1
        for d, ax in zip(sh.shape, tuple(sp) + (None,) * len(sh.shape)):
            axes = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
            k = 1
            for a in axes:
                k *= sizes.get(a, 1)
            n *= -(-d // k)
        return n * sh.dtype.itemsize

    shapes = jax.tree.leaves(shapes_tree)
    specs = jax.tree.leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, P))
    return sum(leaf(sh, sp) for sh, sp in zip(shapes, specs))


def analytical_memory(arch_id: str, shape_name: str, mesh) -> dict:
    """Closed-form per-device HBM model (authoritative 'fits' evidence; the
    CPU backend's memory_analysis() reports a conservative arena that
    double-buffers while-loop carries — see EXPERIMENTS.md §Dry-run)."""
    arch = C.get_arch(arch_id)
    shape = C.SHAPES[shape_name]
    cfg = arch.model
    rules = M.make_rules(mesh, kind=shape.kind,
                         global_batch=shape.global_batch)
    pshapes = T.param_shapes(cfg)
    pspecs = T.param_specs(cfg)
    out = {"params": _sharded_bytes(pshapes, pspecs, mesh)}
    if shape.kind == "train":
        opt = O.make_optimizer(arch.optimizer,
                               state_dtype=arch.opt_state_dtype)
        oshapes = jax.eval_shape(opt.init, pshapes)
        ospecs = opt.init_specs(pspecs, pshapes)
        out["opt_state"] = _sharded_bytes(oshapes, ospecs, mesh)
        out["grads"] = out["params"]
        dsize = M.data_size(mesh)
        tp = mesh.shape.get("model", 1)
        b_loc = -(-shape.global_batch // dsize)
        out["residual_stack"] = (cfg.num_layers * b_loc *
                                 (shape.seq_len // tp) * cfg.d_model *
                                 cfg.dtype.itemsize)
    elif shape.kind == "decode":
        cshapes = T.cache_shapes(cfg, shape.global_batch, shape.seq_len,
                                 rules)
        cspecs = T.cache_specs(cfg, shape.global_batch, shape.seq_len, rules)
        out["kv_cache"] = _sharded_bytes(cshapes, cspecs, mesh)
    out["total"] = sum(out.values())
    return out


def run_cell(arch_id: str, shape_name: str, mesh, mesh_name: str,
             *, smoke: bool = False, want_hlo: bool = False,
             hlo_dir=None) -> dict:
    t0 = time.time()
    fn, args = build_cell(arch_id, shape_name, mesh, smoke=smoke)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    if hlo_dir is not None:
        import gzip
        tag = f"{arch_id}__{shape_name}__{mesh_name}"
        with gzip.open(Path(hlo_dir) / f"{tag}.hlo.txt.gz", "wt") as f:
            f.write(hlo)

    n_dev = mesh.devices.size
    result = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "devices": int(n_dev),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": cost.get("flops", 0.0) if cost else None,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else None,
        "cost_analysis_keys": sorted(cost)[:40] if cost else [],
        "memory": _mem_dict(mem),
        "memory_model": analytical_memory(arch_id, shape_name, mesh)
        if not smoke else {},
        "collectives": coll,
    }
    if want_hlo:
        result["hlo_len"] = len(hlo)
    return result


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for field in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "temp_size_in_bytes", "host_generated_code_size_in_bytes",
                  "host_argument_size_in_bytes", "host_output_size_in_bytes",
                  "host_alias_size_in_bytes", "host_temp_size_in_bytes",
                  "peak_memory_in_bytes"):
        if hasattr(mem, field):
            out[field] = int(getattr(mem, field))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced configs (debugging the harness)")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose .json output already exists")
    ap.add_argument("--dump-hlo", action="store_true",
                    help="write gz-compressed post-SPMD HLO per cell")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    meshes = []
    if args.both_meshes:
        meshes = [("pod16x16", M.make_production_mesh(multi_pod=False)),
                  ("pod2x16x16", M.make_production_mesh(multi_pod=True))]
    else:
        mesh = M.make_production_mesh(multi_pod=args.multi_pod)
        meshes = [("pod2x16x16" if args.multi_pod else "pod16x16", mesh)]

    if args.all:
        todo = [(a, s) for a, s, _ in C.cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        todo = [(args.arch, args.shape)]

    failures = 0
    for mesh_name, mesh in meshes:
        for arch_id, shape_name in todo:
            tag = f"{arch_id}__{shape_name}__{mesh_name}"
            out_file = out_dir / f"{tag}.json"
            if args.skip_existing and out_file.exists():
                print(f"SKIP {tag} (exists)", flush=True)
                continue
            try:
                with mesh:
                    res = run_cell(arch_id, shape_name, mesh, mesh_name,
                                   smoke=args.smoke,
                                   hlo_dir=out_dir if args.dump_hlo
                                   else None)
                out_file.write_text(json.dumps(res, indent=1))
                mem = res["memory"]
                per_dev = (mem.get("argument_size_in_bytes", 0)
                           + mem.get("temp_size_in_bytes", 0)
                           + mem.get("output_size_in_bytes", 0)
                           - mem.get("alias_size_in_bytes", 0))
                print(f"OK   {tag}: compile={res['compile_s']}s "
                      f"flops={res['flops']:.3e} "
                      f"coll={res['collectives']['bytes_by_kind']['total']:.3e}B "
                      f"mem/dev~{per_dev/1e9:.2f}GB", flush=True)
            except Exception as e:  # noqa: BLE001 — sweep must keep going
                failures += 1
                out_file.with_suffix(".err").write_text(
                    "".join(traceback.format_exception(e)))
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
