"""Version compatibility shims for the JAX APIs that moved.

The container pins JAX 0.4.37; newer APIs used by this codebase are
resolved here so every call site stays on the modern spelling.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """`jax.shard_map` (new) or `jax.experimental.shard_map.shard_map`
    (0.4.x, where the replication-check kwarg is spelled ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def axis_size(axis_name):
    """`jax.lax.axis_size` (new) or the classic `psum(1, axis)` spelling."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
